//! Wire-protocol property tests: encode∘decode identity under the
//! lossless codec, bounded error under the lossy codec, loud rejection
//! of corrupt frames, panic-freedom of the decoders under single-byte
//! mutations and arbitrary byte strings, and the acceptance check that
//! measured frame bytes dominate the idealized footnote-5 estimates
//! for every strategy's upload and broadcast shape.

use fetchsgd::compression::aggregate::RoundAccum;
use fetchsgd::compression::{ClientUpload, RoundUpdate, UploadSpec};
use fetchsgd::sketch::{CountSketch, SparseVec};
use fetchsgd::util::proptest::check;
use fetchsgd::wire::{
    decode_update, decode_upload, encode_update, encode_upload, Frame, F16LE, F32LE, HEADER_LEN,
    MAGIC, VERSION,
};

fn random_sketch(g: &mut fetchsgd::util::proptest::Gen) -> CountSketch {
    let rows = 1 + g.usize_in(0, 5);
    let cols = 1 << g.usize_in(4, 9);
    let seed = g.u64();
    let dim = g.usize_in(64, 4000);
    let v = g.vec_f32(dim, dim + 1, -10.0, 10.0);
    CountSketch::encode(rows, cols, seed, &v).unwrap()
}

fn random_sparse(g: &mut fetchsgd::util::proptest::Gen) -> SparseVec {
    let dim = g.usize_in(10, 3000);
    let nnz = g.usize_in(1, 32.min(dim));
    let mut pairs = Vec::new();
    for _ in 0..nnz {
        let i = g.usize_in(0, dim) as u32;
        if pairs.iter().any(|&(j, _)| j == i) {
            continue;
        }
        pairs.push((i, g.f32_in(-100.0, 100.0)));
    }
    SparseVec::from_pairs(dim, pairs)
}

#[test]
fn prop_f32le_roundtrip_is_identity_on_all_payload_kinds() {
    check("wire f32le identity", 40, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 2000, -1e5, 1e5)),
        };
        let frame = encode_upload(&upload, &F32LE);
        assert!(frame.len() as u64 > upload.payload_bytes(), "frames carry overhead");
        match (upload, decode_upload(&frame).unwrap()) {
            (ClientUpload::Sketch(a), ClientUpload::Sketch(b)) => {
                assert_eq!(a.rows(), b.rows());
                assert_eq!(a.cols(), b.cols());
                assert_eq!(a.dim(), b.dim());
                assert_eq!(a.seed(), b.seed());
                let ab: Vec<u32> = a.table().iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.table().iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb);
            }
            (ClientUpload::Sparse(a), ClientUpload::Sparse(b)) => {
                assert_eq!(a.dim, b.dim);
                assert_eq!(a.idx, b.idx);
                let av: Vec<u32> = a.val.iter().map(|x| x.to_bits()).collect();
                let bv: Vec<u32> = b.val.iter().map(|x| x.to_bits()).collect();
                assert_eq!(av, bv);
            }
            (ClientUpload::Dense(a), ClientUpload::Dense(b)) => {
                let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb);
            }
            _ => panic!("payload kind changed across the wire"),
        }
    });
}

#[test]
fn prop_f16le_roundtrip_error_is_bounded_on_all_payload_kinds() {
    let bound = |x: f32| (x.abs() / 2048.0).max(1.0 / (1u64 << 25) as f32);
    check("wire f16le bounded error", 40, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 2000, -1000.0, 1000.0)),
        };
        let frame = encode_upload(&upload, &F16LE);
        let decoded = decode_upload(&frame).unwrap();
        let pairs: (Vec<f32>, Vec<f32>) = match (&upload, &decoded) {
            (ClientUpload::Sketch(a), ClientUpload::Sketch(b)) => {
                (a.table().to_vec(), b.table().to_vec())
            }
            (ClientUpload::Sparse(a), ClientUpload::Sparse(b)) => {
                assert_eq!(a.idx, b.idx, "indices are never quantized");
                (a.val.clone(), b.val.clone())
            }
            (ClientUpload::Dense(a), ClientUpload::Dense(b)) => (a.clone(), b.clone()),
            _ => panic!("payload kind changed across the wire"),
        };
        assert_eq!(pairs.0.len(), pairs.1.len());
        for (x, y) in pairs.0.iter().zip(&pairs.1) {
            assert!((x - y).abs() <= bound(*x), "f16 error {x} -> {y}");
        }
    });
}

#[test]
fn prop_corrupted_frames_never_decode() {
    check("wire corruption rejection", 60, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 500, -10.0, 10.0)),
        };
        let frame = encode_upload(&upload, &F32LE);
        // Truncation anywhere must fail (a short read can't be absorbed).
        let cut = g.usize_in(0, frame.len());
        assert!(decode_upload(&frame[..cut]).is_err(), "accepted a {cut}-byte prefix");
        // Header corruption must fail. (Payload bit flips are
        // legitimately undetectable without a checksum — out of scope.)
        let mut bad = frame.clone();
        let at = g.usize_in(0, 8);
        bad[at] ^= 1 << g.usize_in(0, 8);
        // Flipping the codec id reinterprets the payload length and the
        // length check rejects it; a flipped kind tag dies on shape
        // validation or geometry checks.
        assert!(
            decode_upload(&bad).is_err(),
            "header corruption at byte {at} went unnoticed"
        );
    });
}

/// Decode robustness, half one: a single byte flipped *anywhere* in a
/// valid frame — header or payload, either codec — must never panic
/// the decoders. Header corruption errors (pinned by the test above);
/// a payload flip may legitimately decode (f32 bit flips are
/// undetectable without a checksum) but must return cleanly either
/// way. `check` turns any panic into a replayable failure.
#[test]
fn prop_single_byte_mutations_never_panic_the_decoder() {
    check("wire single-byte mutation robustness", 120, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 500, -10.0, 10.0)),
        };
        let frame = if g.bool() {
            encode_upload(&upload, &F32LE)
        } else {
            encode_upload(&upload, &F16LE)
        };
        let mut bad = frame;
        let at = g.usize_in(0, bad.len());
        bad[at] ^= 1 << g.usize_in(0, 8);
        let _ = decode_upload(&bad);
        let _ = decode_update(&bad);
        if let Ok(parsed) = Frame::parse(&bad) {
            // Whatever still parses must survive validation against
            // specs it does and does not match.
            let _ = UploadSpec::Dense { dim: 100 }.validate_frame(&parsed);
            let _ = UploadSpec::Sketch { rows: 3, cols: 128, dim: 100, seed: 1 }
                .validate_frame(&parsed);
        }
    });
}

/// Decode robustness, half two: arbitrary byte strings — pure noise,
/// and the same noise dressed in a well-formed header prefix so the
/// body parsers (not just the magic check) are exercised — must be
/// handled without panicking. Shape fields here are attacker-chosen
/// u64s, so this is where oversize-claim arithmetic would overflow if
/// the parser trusted them.
#[test]
fn prop_random_byte_strings_never_panic_the_decoder() {
    check("wire random-bytes robustness", 200, |g| {
        let len = g.usize_in(0, 600);
        let mut bytes = Vec::with_capacity(len + 8);
        while bytes.len() < len {
            bytes.extend_from_slice(&g.u64().to_le_bytes());
        }
        bytes.truncate(len);
        let _ = decode_upload(&bytes);
        if bytes.len() >= HEADER_LEN {
            bytes[..4].copy_from_slice(&MAGIC);
            bytes[4] = VERSION;
            bytes[5] = g.usize_in(0, 4) as u8; // codec id, sometimes invalid
            bytes[6] = g.usize_in(0, 5) as u8; // kind tag, sometimes invalid
            bytes[7] = 0;
            if let Ok(parsed) = Frame::parse(&bytes) {
                let _ = UploadSpec::Dense { dim: 64 }.validate_frame(&parsed);
            }
            let _ = decode_upload(&bytes);
            let _ = decode_update(&bytes);
        }
    });
}

#[test]
fn wrong_version_is_rejected() {
    let mut frame = encode_upload(&ClientUpload::Dense(vec![1.0, 2.0]), &F32LE);
    frame[4] = 0;
    assert!(decode_upload(&frame).is_err());
    frame[4] = 2;
    assert!(decode_upload(&frame).is_err());
}

/// Acceptance criterion: for every strategy's upload shape and every
/// broadcast shape, the measured frame length under `f32le` is >= the
/// idealized footnote-5 estimate.
#[test]
fn measured_frame_bytes_dominate_idealized_estimates_for_every_strategy() {
    let dim = 5000;
    let g: Vec<f32> = (0..dim).map(|i| ((i * 37) % 101) as f32 * 0.1 - 5.0).collect();
    // Upload shapes: fetchsgd (sketch), local_topk (sparse), fedavg /
    // uncompressed / true_topk (dense).
    let uploads = vec![
        ("fetchsgd", ClientUpload::Sketch(CountSketch::encode(5, 512, 3, &g).unwrap())),
        ("local_topk", ClientUpload::Sparse(fetchsgd::sketch::topk::top_k_sparse(&g, 50))),
        ("fedavg/uncompressed/true_topk", ClientUpload::Dense(g.clone())),
    ];
    for (name, upload) in &uploads {
        let frame = encode_upload(upload, &F32LE);
        assert!(
            frame.len() as u64 >= upload.payload_bytes(),
            "{name}: measured {} < idealized {}",
            frame.len(),
            upload.payload_bytes()
        );
    }
    // Broadcast shapes: sparse (fetchsgd, top-k) and dense (fedavg,
    // uncompressed).
    let updates = vec![
        ("sparse broadcast", RoundUpdate::Sparse(fetchsgd::sketch::topk::top_k_sparse(&g, 50))),
        ("dense broadcast", RoundUpdate::Dense(g)),
    ];
    for (name, update) in &updates {
        let frame = encode_update(update, &F32LE);
        assert!(
            frame.len() as u64 >= update.payload_bytes(),
            "{name}: measured {} < idealized {}",
            frame.len(),
            update.payload_bytes()
        );
        // and the round trip preserves the update exactly under f32le
        let back = decode_update(&frame).unwrap();
        assert_eq!(back.nnz(), update.nnz());
        assert_eq!(back.payload_bytes(), update.payload_bytes());
    }
}

#[test]
fn lossy_codec_still_shrinks_dense_payloads_below_idealized() {
    // The one place measured < idealized is legitimate: a lossy codec
    // on a dense payload (2 bytes/value beats the 4-byte convention).
    let step: Vec<f32> = (0..10_000).map(|i| (i as f32).cos()).collect();
    let update = RoundUpdate::Dense(step);
    let frame = encode_update(&update, &F16LE);
    assert!((frame.len() as u64) < update.payload_bytes());
    assert!(decode_update(&frame).is_ok());
}

// ---- UploadSpec::validate_frame edge cases the transport relies on ----

/// A zero-length sparse payload (a client whose top-k came up empty) is
/// a *legal* frame: it parses, validates against a dense spec, absorbs
/// as a no-op that still counts toward the cohort, and is rejected by a
/// sketch spec like any other kind mismatch.
#[test]
fn zero_length_sparse_payload_is_legal_and_absorbs_as_a_noop() {
    let dim = 100;
    let empty = SparseVec::from_sorted(dim, Vec::new(), Vec::new()).unwrap();
    let frame = encode_upload(&ClientUpload::Sparse(empty), &F32LE);
    let parsed = Frame::parse(&frame).unwrap();
    UploadSpec::Dense { dim }.validate_frame(&parsed).unwrap();
    assert!(UploadSpec::Sketch { rows: 3, cols: 128, dim, seed: 1 }
        .validate_frame(&parsed)
        .is_err());

    let mut acc = RoundAccum::new(&UploadSpec::Dense { dim }).unwrap();
    acc.absorb_bytes(&frame, 1.0).unwrap();
    assert_eq!(acc.absorbed(), 1, "an empty upload still counts toward the cohort");
    assert!(acc.as_dense().unwrap().iter().all(|&x| x == 0.0));
}

/// A sparse frame claiming more nonzeros than the dimension (k > d) is
/// structurally impossible and must die at parse, before validation or
/// absorption ever sees it.
#[test]
fn sparse_frame_claiming_k_greater_than_d_is_rejected_at_parse() {
    let dim = 50u64;
    let sv = SparseVec::from_pairs(dim as usize, vec![(1, 1.0), (7, -2.0)]);
    let mut frame = encode_upload(&ClientUpload::Sparse(sv), &F32LE);
    // Sparse shape header: dim u64 at HEADER_LEN, nnz u64 right after.
    let nnz_at = HEADER_LEN + 8;
    frame[nnz_at..nnz_at + 8].copy_from_slice(&(dim + 1).to_le_bytes());
    let err = decode_upload(&frame).unwrap_err().to_string();
    assert!(err.contains("claims"), "{err}");
}

/// A sketch frame whose geometry is off by a single row parses fine
/// (it is a valid sketch — just not *this round's* sketch) and must be
/// caught by `validate_frame` / `absorb_bytes`, the seam the transport
/// server trusts.
#[test]
fn sketch_shape_mismatch_by_one_row_is_rejected_by_validate_frame() {
    let dim = 500;
    let g: Vec<f32> = (0..dim).map(|i| (i as f32 * 0.3).sin()).collect();
    let spec = UploadSpec::Sketch { rows: 3, cols: 128, dim, seed: 9 };
    let off_by_one = CountSketch::encode(4, 128, 9, &g).unwrap();
    let frame = encode_upload(&ClientUpload::Sketch(off_by_one), &F32LE);
    let parsed = Frame::parse(&frame).unwrap();
    let err = spec.validate_frame(&parsed).unwrap_err().to_string();
    assert!(err.contains("incompatible"), "{err}");
    let mut acc = RoundAccum::new(&spec).unwrap();
    assert!(acc.absorb_bytes(&frame, 1.0).is_err());
    assert_eq!(acc.absorbed(), 0);
    // The matching geometry sails through.
    let ok = CountSketch::encode(3, 128, 9, &g).unwrap();
    acc.absorb_bytes(&encode_upload(&ClientUpload::Sketch(ok), &F32LE), 1.0).unwrap();
}

/// The f16le broadcast round-trip for each strategy's update shape:
/// sparse (fetchsgd / local top-k / true top-k) and dense (fedavg /
/// uncompressed). Kind and indices must survive exactly; values within
/// the binary16 error bound.
#[test]
fn f16le_broadcast_roundtrip_per_strategy_shape() {
    let bound = |x: f32| (x.abs() / 2048.0).max(1.0 / (1u64 << 25) as f32);
    let dim = 2000;
    let g: Vec<f32> = (0..dim).map(|i| ((i * 13) % 89) as f32 * 0.25 - 11.0).collect();
    let sparse = RoundUpdate::Sparse(fetchsgd::sketch::topk::top_k_sparse(&g, 40));
    let dense = RoundUpdate::Dense(g.clone());
    for (name, update) in [("sparse", &sparse), ("dense", &dense)] {
        let frame = encode_update(update, &F16LE);
        let back = decode_update(&frame).unwrap();
        match (update, &back) {
            (RoundUpdate::Sparse(a), RoundUpdate::Sparse(b)) => {
                assert_eq!(a.idx, b.idx, "{name}: indices are never quantized");
                for (x, y) in a.val.iter().zip(&b.val) {
                    assert!((x - y).abs() <= bound(*x), "{name}: {x} -> {y}");
                }
            }
            (RoundUpdate::Dense(a), RoundUpdate::Dense(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert!((x - y).abs() <= bound(*x), "{name}: {x} -> {y}");
                }
            }
            _ => panic!("{name}: broadcast kind changed across the wire"),
        }
        // Applying the decoded broadcast must be well-formed for the
        // trainer's weight vector.
        let mut w = vec![0f32; dim];
        back.apply(&mut w);
        assert!(w.iter().any(|&x| x != 0.0));
    }
}
