//! Wire-protocol property tests: encode∘decode identity under the
//! lossless codec, bounded error under the lossy codec, loud rejection
//! of corrupt frames, and the acceptance check that measured frame
//! bytes dominate the idealized footnote-5 estimates for every
//! strategy's upload and broadcast shape.

use fetchsgd::compression::{ClientUpload, RoundUpdate};
use fetchsgd::sketch::{CountSketch, SparseVec};
use fetchsgd::util::proptest::check;
use fetchsgd::wire::{decode_update, decode_upload, encode_update, encode_upload, F16LE, F32LE};

fn random_sketch(g: &mut fetchsgd::util::proptest::Gen) -> CountSketch {
    let rows = 1 + g.usize_in(0, 5);
    let cols = 1 << g.usize_in(4, 9);
    let seed = g.u64();
    let dim = g.usize_in(64, 4000);
    let v = g.vec_f32(dim, dim + 1, -10.0, 10.0);
    CountSketch::encode(rows, cols, seed, &v).unwrap()
}

fn random_sparse(g: &mut fetchsgd::util::proptest::Gen) -> SparseVec {
    let dim = g.usize_in(10, 3000);
    let nnz = g.usize_in(1, 32.min(dim));
    let mut pairs = Vec::new();
    for _ in 0..nnz {
        let i = g.usize_in(0, dim) as u32;
        if pairs.iter().any(|&(j, _)| j == i) {
            continue;
        }
        pairs.push((i, g.f32_in(-100.0, 100.0)));
    }
    SparseVec::from_pairs(dim, pairs)
}

#[test]
fn prop_f32le_roundtrip_is_identity_on_all_payload_kinds() {
    check("wire f32le identity", 40, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 2000, -1e5, 1e5)),
        };
        let frame = encode_upload(&upload, &F32LE);
        assert!(frame.len() as u64 > upload.payload_bytes(), "frames carry overhead");
        match (upload, decode_upload(&frame).unwrap()) {
            (ClientUpload::Sketch(a), ClientUpload::Sketch(b)) => {
                assert_eq!(a.rows(), b.rows());
                assert_eq!(a.cols(), b.cols());
                assert_eq!(a.dim(), b.dim());
                assert_eq!(a.seed(), b.seed());
                let ab: Vec<u32> = a.table().iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.table().iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb);
            }
            (ClientUpload::Sparse(a), ClientUpload::Sparse(b)) => {
                assert_eq!(a.dim, b.dim);
                assert_eq!(a.idx, b.idx);
                let av: Vec<u32> = a.val.iter().map(|x| x.to_bits()).collect();
                let bv: Vec<u32> = b.val.iter().map(|x| x.to_bits()).collect();
                assert_eq!(av, bv);
            }
            (ClientUpload::Dense(a), ClientUpload::Dense(b)) => {
                let ab: Vec<u32> = a.iter().map(|x| x.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|x| x.to_bits()).collect();
                assert_eq!(ab, bb);
            }
            _ => panic!("payload kind changed across the wire"),
        }
    });
}

#[test]
fn prop_f16le_roundtrip_error_is_bounded_on_all_payload_kinds() {
    let bound = |x: f32| (x.abs() / 2048.0).max(1.0 / (1u64 << 25) as f32);
    check("wire f16le bounded error", 40, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 2000, -1000.0, 1000.0)),
        };
        let frame = encode_upload(&upload, &F16LE);
        let decoded = decode_upload(&frame).unwrap();
        let pairs: (Vec<f32>, Vec<f32>) = match (&upload, &decoded) {
            (ClientUpload::Sketch(a), ClientUpload::Sketch(b)) => {
                (a.table().to_vec(), b.table().to_vec())
            }
            (ClientUpload::Sparse(a), ClientUpload::Sparse(b)) => {
                assert_eq!(a.idx, b.idx, "indices are never quantized");
                (a.val.clone(), b.val.clone())
            }
            (ClientUpload::Dense(a), ClientUpload::Dense(b)) => (a.clone(), b.clone()),
            _ => panic!("payload kind changed across the wire"),
        };
        assert_eq!(pairs.0.len(), pairs.1.len());
        for (x, y) in pairs.0.iter().zip(&pairs.1) {
            assert!((x - y).abs() <= bound(*x), "f16 error {x} -> {y}");
        }
    });
}

#[test]
fn prop_corrupted_frames_never_decode() {
    check("wire corruption rejection", 60, |g| {
        let upload = match g.usize_in(0, 3) {
            0 => ClientUpload::Sketch(random_sketch(g)),
            1 => ClientUpload::Sparse(random_sparse(g)),
            _ => ClientUpload::Dense(g.vec_f32(1, 500, -10.0, 10.0)),
        };
        let frame = encode_upload(&upload, &F32LE);
        // Truncation anywhere must fail (a short read can't be absorbed).
        let cut = g.usize_in(0, frame.len());
        assert!(decode_upload(&frame[..cut]).is_err(), "accepted a {cut}-byte prefix");
        // Header corruption must fail. (Payload bit flips are
        // legitimately undetectable without a checksum — out of scope.)
        let mut bad = frame.clone();
        let at = g.usize_in(0, 8);
        bad[at] ^= 1 << g.usize_in(0, 8);
        // Flipping the codec id reinterprets the payload length and the
        // length check rejects it; a flipped kind tag dies on shape
        // validation or geometry checks.
        assert!(
            decode_upload(&bad).is_err(),
            "header corruption at byte {at} went unnoticed"
        );
    });
}

#[test]
fn wrong_version_is_rejected() {
    let mut frame = encode_upload(&ClientUpload::Dense(vec![1.0, 2.0]), &F32LE);
    frame[4] = 0;
    assert!(decode_upload(&frame).is_err());
    frame[4] = 2;
    assert!(decode_upload(&frame).is_err());
}

/// Acceptance criterion: for every strategy's upload shape and every
/// broadcast shape, the measured frame length under `f32le` is >= the
/// idealized footnote-5 estimate.
#[test]
fn measured_frame_bytes_dominate_idealized_estimates_for_every_strategy() {
    let dim = 5000;
    let g: Vec<f32> = (0..dim).map(|i| ((i * 37) % 101) as f32 * 0.1 - 5.0).collect();
    // Upload shapes: fetchsgd (sketch), local_topk (sparse), fedavg /
    // uncompressed / true_topk (dense).
    let uploads = vec![
        ("fetchsgd", ClientUpload::Sketch(CountSketch::encode(5, 512, 3, &g).unwrap())),
        ("local_topk", ClientUpload::Sparse(fetchsgd::sketch::topk::top_k_sparse(&g, 50))),
        ("fedavg/uncompressed/true_topk", ClientUpload::Dense(g.clone())),
    ];
    for (name, upload) in &uploads {
        let frame = encode_upload(upload, &F32LE);
        assert!(
            frame.len() as u64 >= upload.payload_bytes(),
            "{name}: measured {} < idealized {}",
            frame.len(),
            upload.payload_bytes()
        );
    }
    // Broadcast shapes: sparse (fetchsgd, top-k) and dense (fedavg,
    // uncompressed).
    let updates = vec![
        ("sparse broadcast", RoundUpdate::Sparse(fetchsgd::sketch::topk::top_k_sparse(&g, 50))),
        ("dense broadcast", RoundUpdate::Dense(g)),
    ];
    for (name, update) in &updates {
        let frame = encode_update(update, &F32LE);
        assert!(
            frame.len() as u64 >= update.payload_bytes(),
            "{name}: measured {} < idealized {}",
            frame.len(),
            update.payload_bytes()
        );
        // and the round trip preserves the update exactly under f32le
        let back = decode_update(&frame).unwrap();
        assert_eq!(back.nnz(), update.nnz());
        assert_eq!(back.payload_bytes(), update.payload_bytes());
    }
}

#[test]
fn lossy_codec_still_shrinks_dense_payloads_below_idealized() {
    // The one place measured < idealized is legitimate: a lossy codec
    // on a dense payload (2 bytes/value beats the 4-byte convention).
    let step: Vec<f32> = (0..10_000).map(|i| (i as f32).cos()).collect();
    let update = RoundUpdate::Dense(step);
    let frame = encode_update(&update, &F16LE);
    assert!((frame.len() as u64) < update.payload_bytes());
    assert!(decode_update(&frame).is_ok());
}
