//! Shared fault-injection harness for the socket suites.
//!
//! Every scripted peer the transport, straggler, and relay tests need
//! lives here: well-behaved workers (one-shot, persistent, gated),
//! hostile workers (corrupt frame, slow-loris byte-at-a-time writer,
//! truncation, oversize prefix, wrong slot), hostile relay peers
//! (corrupt merged frame, mid-merge vanish), and wrong-version hellos
//! for both tiers. Each test binary includes this file with
//! `#[path = "common/faults.rs"] mod faults;` — it is not a cargo
//! target of its own, so unused helpers per binary are expected.
//!
//! The scripted gradient shape is fixed ([`DIM`], [`HEAVY`]): small
//! enough that a fault round costs milliseconds, real enough that a
//! recovery round moves the model. Peers that never encode a gradient
//! (the relay evils, the hellos) are shape-free and reusable at any
//! dimension.
#![allow(dead_code)]

use std::io::Write;
use std::sync::mpsc;
use std::time::Duration;

use fetchsgd::compression::sim::synth_grad;
use fetchsgd::compression::ClientUpload;
use fetchsgd::transport::framing::{read_msg, write_msg};
use fetchsgd::transport::proto::{Msg, PROTO_VERSION};
use fetchsgd::transport::{Conn, Endpoint};
use fetchsgd::wire::{encode_upload, F32LE};

/// Gradient shape every scripted worker in this harness uploads.
pub const DIM: usize = 64;
pub const HEAVY: usize = 2;
/// Message cap generous enough for any frame these tests produce.
pub const MAX_MSG: usize = 64 << 20;
/// Socket timeout for scripted peers: long enough to never fire on a
/// healthy exchange, short enough that a wedged test still fails.
pub const PEER_TIMEOUT: Duration = Duration::from_secs(20);

/// Dial an endpoint with the harness timeouts applied.
pub fn dial(ep: &Endpoint) -> Conn {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(PEER_TIMEOUT), Some(PEER_TIMEOUT)).unwrap();
    conn
}

/// Handshake as a worker and wait for the round start; returns the
/// round seed and this connection's slot assignments.
pub fn start_round(conn: &mut Conn) -> (u64, Vec<(u32, u32)>) {
    write_msg(conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(conn, MAX_MSG).unwrap();
    match Msg::decode(bytes).unwrap() {
        Msg::RoundStart { round_seed, assignments, .. } => (round_seed, assignments),
        other => panic!("expected round-start, got {}", other.kind_name()),
    }
}

/// Handshake as a relay and wait for the round's subtree; returns the
/// round, seed, and `(slot, client, weight)` entries.
pub fn start_subtree(conn: &mut Conn) -> (u64, u64, Vec<(u32, u32, f32)>) {
    write_msg(conn, &Msg::RelayHello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(conn, MAX_MSG).unwrap();
    match Msg::decode(bytes).unwrap() {
        Msg::SubtreeAssign { round, round_seed, entries, .. } => (round, round_seed, entries),
        other => panic!("expected subtree-assign, got {}", other.kind_name()),
    }
}

/// The deterministic dense upload frame a well-behaved worker would
/// send for `client` under `seed` — the raw material every corrupting
/// peer mutates.
pub fn valid_dense_frame(seed: u64, client: u32) -> Vec<u8> {
    let g = synth_grad(DIM, HEAVY, client as usize, seed);
    encode_upload(&ClientUpload::Dense(g), &F32LE)
}

/// A well-behaved hand-rolled worker for one round: uploads the same
/// deterministic dense gradient the sim client would, then reads until
/// the server says abort / round-end / EOF.
pub fn good_worker(ep: &Endpoint) {
    let mut conn = dial(ep);
    let (seed, assignments) = start_round(&mut conn);
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        write_msg(&mut conn, &Msg::Upload { slot, loss: 0.25, frame }.encode()).unwrap();
    }
    // Round-end on success, abort (or a dropped conn) on failure —
    // either way this worker is done.
    if let Ok((bytes, _)) = read_msg(&mut conn, MAX_MSG) {
        match Msg::decode(bytes).unwrap() {
            Msg::RoundEnd { .. } | Msg::Abort { .. } => {}
            other => panic!("unexpected {} after upload", other.kind_name()),
        }
    }
}

/// A worker that serves rounds until the server (or its relay) says
/// `Shutdown` — the persistent twin of [`good_worker`], so a relay tier
/// can keep it across a whole test.
pub fn persistent_dense_worker(ep: &Endpoint) {
    let mut conn = dial(ep);
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    loop {
        let Ok((bytes, _)) = read_msg(&mut conn, MAX_MSG) else { return };
        match Msg::decode(bytes).unwrap() {
            Msg::RoundStart { round_seed, assignments, .. } => {
                for (slot, client) in assignments {
                    let g = synth_grad(DIM, HEAVY, client as usize, round_seed);
                    let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
                    let msg = Msg::Upload { slot, loss: 0.25, frame };
                    if write_msg(&mut conn, &msg.encode()).is_err() {
                        return;
                    }
                }
            }
            Msg::RoundEnd { .. } => {}
            Msg::Shutdown | Msg::Abort { .. } => return,
            other => panic!("unexpected {} message", other.kind_name()),
        }
    }
}

/// A worker that withholds its uploads until `gate` opens (None = no
/// wait), then serves the round and drains round-end + shutdown. The
/// straggler suite's prompt workers pass `None`; the straggler passes
/// the gated receiver.
pub fn gated_worker(ep: &Endpoint, gate: Option<mpsc::Receiver<()>>) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(&mut conn, MAX_MSG).unwrap();
    let (seed, assignments) = match Msg::decode(bytes).unwrap() {
        Msg::RoundStart { round_seed, assignments, .. } => (round_seed, assignments),
        _ => panic!("expected round-start"),
    };
    if let Some(rx) = gate {
        rx.recv_timeout(Duration::from_secs(30)).expect("straggler gate never released");
    }
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        write_msg(&mut conn, &Msg::Upload { slot, loss: 0.5, frame }.encode()).unwrap();
    }
    loop {
        let (bytes, _) = read_msg(&mut conn, MAX_MSG).unwrap();
        match Msg::decode(bytes).unwrap() {
            Msg::RoundEnd { .. } => {}
            Msg::Shutdown => break,
            other => panic!("unexpected {}", other.kind_name()),
        }
    }
}

/// A straggler that withholds its upload until the gate opens and
/// tolerates every error afterwards — under a round deadline the server
/// legitimately drops its connection before it ever uploads.
pub fn tolerant_straggler(ep: &Endpoint, rx: mpsc::Receiver<()>) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let Ok((bytes, _)) = read_msg(&mut conn, MAX_MSG) else { return };
    let (seed, assignments) = match Msg::decode(bytes) {
        Ok(Msg::RoundStart { round_seed, assignments, .. }) => (round_seed, assignments),
        _ => return,
    };
    let _ = rx.recv_timeout(Duration::from_secs(30));
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        let _ = write_msg(&mut conn, &Msg::Upload { slot, loss: 0.5, frame }.encode());
    }
}

/// One evil worker behavior, injected after a legitimate handshake +
/// round-start so the fault lands mid-round where it hurts. Arguments:
/// the connection, the first assigned slot, the round seed.
pub type Evil = fn(&mut Conn, u32, u64);

pub fn evil_truncated_frame(conn: &mut Conn, slot: u32, seed: u64) {
    let mut frame = valid_dense_frame(seed, slot);
    frame.truncate(frame.len() - 3);
    write_msg(conn, &Msg::Upload { slot, loss: 0.0, frame }.encode()).unwrap();
}

pub fn evil_corrupt_magic(conn: &mut Conn, slot: u32, seed: u64) {
    let mut frame = valid_dense_frame(seed, slot);
    frame[0] = b'X';
    write_msg(conn, &Msg::Upload { slot, loss: 0.0, frame }.encode()).unwrap();
}

pub fn evil_wrong_version(conn: &mut Conn, slot: u32, seed: u64) {
    let mut frame = valid_dense_frame(seed, slot);
    frame[4] = 99;
    write_msg(conn, &Msg::Upload { slot, loss: 0.0, frame }.encode()).unwrap();
}

pub fn evil_midstream_disconnect(conn: &mut Conn, _slot: u32, _seed: u64) {
    // Claim a 4096-byte message, deliver 10 bytes, vanish.
    conn.write_all(&4096u32.to_le_bytes()).unwrap();
    conn.write_all(&[7u8; 10]).unwrap();
    conn.flush().unwrap();
    conn.shutdown();
}

pub fn evil_oversize_prefix(conn: &mut Conn, _slot: u32, _seed: u64) {
    conn.write_all(&u32::MAX.to_le_bytes()).unwrap();
    conn.flush().unwrap();
}

pub fn evil_wrong_slot(conn: &mut Conn, _slot: u32, seed: u64) {
    let frame = valid_dense_frame(seed, 0);
    write_msg(conn, &Msg::Upload { slot: 999, loss: 0.0, frame }.encode()).unwrap();
}

/// Slow-loris: trickle the start of a valid upload one byte at a time,
/// then stall with the connection held open — the classic attack a
/// round deadline exists to bound. Each trickled byte keeps the
/// per-read socket timeout from firing, so only a wall-clock deadline
/// can evict this peer. Never completes the message; lingers until the
/// server drops the connection.
pub fn evil_slow_loris(conn: &mut Conn, slot: u32, seed: u64) {
    let body = Msg::Upload { slot, loss: 0.5, frame: valid_dense_frame(seed, slot) }.encode();
    let mut wire = (body.len() as u32).to_le_bytes().to_vec();
    wire.extend_from_slice(&body);
    for &b in wire.iter().take(8) {
        if conn.write_all(&[b]).is_err() || conn.flush().is_err() {
            return;
        }
        std::thread::sleep(Duration::from_millis(150));
    }
    let _ = read_msg(conn, MAX_MSG);
}

/// A peer speaking the wrong *transport* protocol version: sends a
/// worker or relay hello one version ahead and expects an abort (or a
/// plain close) — never a round.
pub fn wrong_version_hello(ep: &Endpoint, relay: bool) {
    let mut conn = dial(ep);
    let hello = if relay {
        Msg::RelayHello { version: PROTO_VERSION + 1 }
    } else {
        Msg::Hello { version: PROTO_VERSION + 1 }
    };
    write_msg(&mut conn, &hello.encode()).unwrap();
    if let Ok((bytes, _)) = read_msg(&mut conn, 1 << 20) {
        assert!(matches!(Msg::decode(bytes).unwrap(), Msg::Abort { .. }));
    }
}

/// Hostile relay peer: reports claim every slot arrived, but the merged
/// frame is garbage — the parent must reject the frame *before*
/// recording any of the claimed outcomes. Lingers until aborted so the
/// failure is the bad merge, not a racing disconnect.
pub fn evil_corrupt_merged(conn: &mut Conn) {
    use fetchsgd::transport::proto::{SlotReport, OUTCOME_ARRIVED};

    let (round, round_seed, entries) = start_subtree(conn);
    let reports = entries
        .iter()
        .map(|&(slot, _, _)| SlotReport { slot, outcome: OUTCOME_ARRIVED, retries: 0, loss: 0.5 })
        .collect();
    let mut frame = valid_dense_frame(round_seed, 0);
    frame[0] = b'X';
    write_msg(conn, &Msg::SubtreeUpload { round, reports, frame }.encode()).unwrap();
    let _ = read_msg(conn, MAX_MSG);
}

/// Hostile relay peer: accepts the subtree, claims a big merged upload,
/// delivers 10 bytes, and vanishes mid-merge.
pub fn evil_vanish_mid_merge(conn: &mut Conn) {
    let _ = start_subtree(conn);
    conn.write_all(&4096u32.to_le_bytes()).unwrap();
    conn.write_all(&[7u8; 10]).unwrap();
    conn.flush().unwrap();
    conn.shutdown();
}
