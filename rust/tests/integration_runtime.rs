//! Integration tests over the real AOT artifacts (require
//! `make artifacts`; they skip politely when artifacts are absent so
//! `cargo test` works on a fresh checkout).
//!
//! These tests pin the L1/L2/L3 contract: the HLO a JAX+Pallas pipeline
//! lowered yesterday must keep producing numbers the Rust side agrees
//! with today.

use std::path::PathBuf;
use std::sync::Arc;

use fetchsgd::model::{build_dataset, DataScale};
use fetchsgd::runtime::artifact::{Manifest, TaskArtifacts};
use fetchsgd::runtime::exec::{run_client_grad, run_client_step, run_eval, run_fedavg};
use fetchsgd::runtime::Runtime;
use fetchsgd::sketch::CountSketch;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn smoke_setup(runtime: Arc<Runtime>, dir: &PathBuf) -> (TaskArtifacts, Vec<f32>) {
    let manifest = Manifest::load(dir).unwrap();
    let arts = TaskArtifacts::new(runtime, &manifest, "smoke").unwrap();
    let w = arts.init_weights().unwrap();
    (arts, w)
}

#[test]
fn manifest_loads_and_lists_tasks() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    assert!(manifest.task("smoke").is_ok());
    let tm = manifest.task("smoke").unwrap();
    assert!(tm.dim > 0);
    assert!(tm.artifacts.contains_key("client_grad"));
    assert!(tm.artifacts.contains_key("eval"));
}

#[test]
fn cross_language_sketch_equality() {
    // The central integration invariant: sketch computed by the Pallas
    // kernel *inside* the HLO graph == sketch computed by the Rust
    // CountSketch on the gradient from the same graph.
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, w) = smoke_setup(runtime, &dir);
    let tm = arts.manifest.clone();
    let cols = tm.sketch.cols_options[0];
    let ds = build_dataset(&tm, &DataScale::smoke()).unwrap();

    for client in [0usize, 3, 11] {
        let batch = ds.client_batch(client, 42);
        let step = arts.executable(&TaskArtifacts::client_step_kind(cols)).unwrap();
        let (loss1, sk) =
            run_client_step(&step, &w, &batch, tm.sketch.rows, cols, tm.sketch.seed).unwrap();
        let grad_exe = arts.executable("client_grad").unwrap();
        let (loss2, grad) = run_client_grad(&grad_exe, &w, &batch).unwrap();
        assert!((loss1 - loss2).abs() < 1e-5);
        let rust_sk = CountSketch::encode(tm.sketch.rows, cols, tm.sketch.seed, &grad).unwrap();
        let gmax = grad.iter().fold(0f32, |a, &b| a.max(b.abs())).max(1.0);
        for (a, b) in sk.table().iter().zip(rust_sk.table()) {
            assert!((a - b).abs() < 1e-4 * gmax, "client {client}: {a} vs {b}");
        }
    }
}

#[test]
fn gradients_are_finite_and_nonzero() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, w) = smoke_setup(runtime, &dir);
    let ds = build_dataset(&arts.manifest, &DataScale::smoke()).unwrap();
    let batch = ds.client_batch(1, 1);
    let exe = arts.executable("client_grad").unwrap();
    let (loss, grad) = run_client_grad(&exe, &w, &batch).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(grad.iter().all(|g| g.is_finite()));
    assert!(grad.iter().any(|&g| g != 0.0));
}

#[test]
fn gradient_matches_finite_differences() {
    // Spot-check d/dw of the loss against central differences on a few
    // coordinates — validates the whole lower-to-execute pipeline, not
    // just shapes.
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, w) = smoke_setup(runtime, &dir);
    let ds = build_dataset(&arts.manifest, &DataScale::smoke()).unwrap();
    let batch = ds.client_batch(0, 9);
    let exe = arts.executable("client_grad").unwrap();
    let (_, grad) = run_client_grad(&exe, &w, &batch).unwrap();

    // pick the largest-|grad| coordinate plus a couple of fixed ones
    let mut probe: Vec<usize> = vec![0, w.len() / 2];
    let max_i =
        grad.iter().enumerate().max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap()).unwrap().0;
    probe.push(max_i);
    let eps = 1e-3f32;
    for &i in &probe {
        let mut wp = w.clone();
        wp[i] += eps;
        let (lp, _) = run_client_grad(&exe, &wp, &batch).unwrap();
        let mut wm = w.clone();
        wm[i] -= eps;
        let (lm, _) = run_client_grad(&exe, &wm, &batch).unwrap();
        let fd = (lp - lm) / (2.0 * eps);
        let g = grad[i];
        assert!(
            (fd - g).abs() < 1e-2 * g.abs().max(0.1),
            "coord {i}: finite-diff {fd} vs grad {g}"
        );
    }
}

#[test]
fn eval_stats_are_consistent() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, w) = smoke_setup(runtime, &dir);
    let ds = build_dataset(&arts.manifest, &DataScale::smoke()).unwrap();
    let exe = arts.executable("eval").unwrap();
    let batch = ds.eval_batch(0);
    let (sum_ce, units, correct) = run_eval(&exe, &w, &batch).unwrap();
    assert!(units > 0.0 && units <= arts.manifest.batch as f64);
    assert!(correct >= 0.0 && correct <= units);
    assert!(sum_ce.is_finite() && sum_ce > 0.0);
}

#[test]
fn fedavg_delta_zero_at_zero_lr_and_descends_otherwise() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, w) = smoke_setup(runtime, &dir);
    let tm = arts.manifest.clone();
    let k = tm.fedavg_steps[0];
    let ds = build_dataset(&tm, &DataScale::smoke()).unwrap();
    let (xs, ys, ms) = ds.client_batches_stacked(0, k, 5);
    let exe = arts.executable(&TaskArtifacts::fedavg_kind(k)).unwrap();

    let (_, delta0) = run_fedavg(&exe, &w, xs.clone(), ys.clone(), ms.clone(), 0.0).unwrap();
    assert!(delta0.iter().all(|&d| d == 0.0), "zero lr must give zero delta");

    let (loss, delta) = run_fedavg(&exe, &w, xs.clone(), ys.clone(), ms.clone(), 0.05).unwrap();
    assert!(loss.is_finite());
    assert!(delta.iter().any(|&d| d != 0.0));
    // Applying the delta (w' = w - delta... note delta = w_in - w_out, so
    // w_out = w - delta) must reduce loss on the same local data.
    let w2: Vec<f32> = w.iter().zip(&delta).map(|(&a, &b)| a - b).collect();
    let (loss2, _) = run_fedavg(&exe, &w2, xs, ys, ms, 0.0).unwrap();
    assert!(loss2 < loss, "local steps should reduce local loss: {loss} -> {loss2}");
}

#[test]
fn unknown_artifact_kind_errors_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let (arts, _) = smoke_setup(runtime, &dir);
    let err = match arts.executable("nonexistent_kind") {
        Ok(_) => panic!("expected error for unknown artifact kind"),
        Err(e) => e,
    };
    assert!(format!("{err:#}").contains("no artifact"));
}
