//! Transport fault injection: every malformed or hostile peer behavior
//! must fail the round *loudly* — never panic the server, never let a
//! byte reach an accumulator — and leave the server reusable for the
//! next round.
//!
//! One server instance survives the whole gauntlet: truncated frame,
//! corrupt magic, wrong frame version, mid-stream disconnect, oversize
//! length prefix, and an out-of-assignment slot. After each fault a
//! clean recovery round runs on fresh connections; at the end the
//! weights must be bitwise identical to an in-process reference that
//! saw only the successful rounds — proving no fault left a fingerprint
//! on round state.
//!
//! The relay tier gets its own gauntlet: a hostile *relay* peer
//! (corrupt merged frame, mid-merge disconnect, wrong-version
//! `RelayHello`) must cost exactly its own subtree — the sibling
//! subtree's slots survive, the round closes at quorum, and the root
//! stays reusable.
//!
//! The scripted peers live in the shared harness (`common/faults.rs`),
//! reused by the straggler and relay suites.

use std::time::Duration;

use fetchsgd::compression::aggregate::run_server_round;
use fetchsgd::compression::sim::{sim_artifacts, synth_grad, SimDataset, SimDenseClient};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::ClientUpload;
use fetchsgd::transport::framing::read_msg;
use fetchsgd::transport::{
    join, Conn, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions,
};

#[path = "common/faults.rs"]
mod faults;
use faults::{
    dial, evil_corrupt_magic, evil_corrupt_merged, evil_midstream_disconnect,
    evil_oversize_prefix, evil_truncated_frame, evil_vanish_mid_merge, evil_wrong_slot,
    evil_wrong_version, good_worker, persistent_dense_worker, start_round, wrong_version_hello,
    Evil, DIM, HEAVY, MAX_MSG,
};

const NUM_CLIENTS: usize = 10;
const LR: f32 = 0.05;

fn round_seed(k: u64) -> u64 {
    0x5EED_0000 ^ (k * 7919)
}

#[test]
fn faults_fail_loudly_and_leave_the_server_reusable() {
    let cases: Vec<(&str, Evil, &str)> = vec![
        ("truncated frame", evil_truncated_frame, "wire payload"),
        ("corrupt magic", evil_corrupt_magic, "magic"),
        ("wrong frame version", evil_wrong_version, "version"),
        ("mid-stream disconnect", evil_midstream_disconnect, "message body"),
        ("oversize length prefix", evil_oversize_prefix, "message cap"),
        ("out-of-assignment slot", evil_wrong_slot, "next on this connection"),
    ];

    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 2,
        read_timeout: Duration::from_secs(10),
        accept_timeout: Duration::from_secs(20),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants = [0usize, 1];
    let sizes = [1.0f32, 1.0];
    let mut successful_seeds = Vec::new();
    let mut round = 0u64;

    for (name, evil, expect) in cases {
        // Fault round: one good worker, one evil worker.
        let seed = round_seed(round);
        std::thread::scope(|s| {
            let ep = actual.clone();
            s.spawn(move || good_worker(&ep));
            let ep = actual.clone();
            s.spawn(move || {
                let mut conn = dial(&ep);
                let (seed, assignments) = start_round(&mut conn);
                let slot = assignments.first().map(|&(s, _)| s).unwrap_or(0);
                evil(&mut conn, slot, seed);
                // Stay alive until the server aborts us so the failure
                // is the bad bytes, not a racing disconnect.
                let _ = read_msg(&mut conn, MAX_MSG);
            });
            let params = RoundParams {
                round,
                round_seed: seed,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let err = srv.run_round(&mut agg, &params, &mut w).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(expect), "{name}: error was: {msg}");
        });
        assert_eq!(srv.connected(), 0, "{name}: faulted round must drop its connections");
        round += 1;

        // Recovery round: two good workers on fresh connections. The
        // server — same instance, same scratch pool — must serve it
        // cleanly.
        let seed = round_seed(round);
        std::thread::scope(|s| {
            for _ in 0..2 {
                let ep = actual.clone();
                s.spawn(move || good_worker(&ep));
            }
            let params = RoundParams {
                round,
                round_seed: seed,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv
                .run_round(&mut agg, &params, &mut w)
                .unwrap_or_else(|e| panic!("{name}: recovery round failed: {e:#}"));
            assert_eq!(stats.losses.len(), 2);
            assert!(stats.wire_upload_bytes_per_client > 0);
        });
        srv.shutdown();
        successful_seeds.push(seed);
        round += 1;
    }

    // No fault may have left a fingerprint: the weights equal an
    // in-process reference that saw only the successful rounds.
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    for &seed in &successful_seeds {
        let uploads: Vec<ClientUpload> = participants
            .iter()
            .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, seed)))
            .collect();
        run_server_round(&mut agg_ref, &sizes, uploads, &mut w_ref, LR).unwrap();
    }
    assert!(w_ref.iter().any(|&x| x != 0.0), "recovery rounds must move the model");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(
        bits(&w_ref),
        bits(&w),
        "a faulted round scribbled on the accumulator or model state"
    );
}

/// Regression for the PR-6 pre-lock validation fix: a corrupt upload
/// frame is rejected *before* any round-state lock is taken, so the
/// slot is never claimed by garbage — under a tolerant quorum policy it
/// is reassigned to a healthy worker and the round completes with every
/// slot arrived, bitwise identical to a clean in-process round.
#[test]
fn corrupt_frame_slot_is_retryable_and_round_completes() {
    use fetchsgd::cohort::QuorumPolicy;

    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 2,
        read_timeout: Duration::from_secs(10),
        accept_timeout: Duration::from_secs(20),
        // Full quorum + retry budget: the round may only succeed if the
        // corrupted slot really is re-offered and served.
        quorum: QuorumPolicy::new(1.0, 0, 2).unwrap(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants = [0usize, 1];
    let sizes = [1.0f32, 1.0];
    let seed = round_seed(77);

    let stats = std::thread::scope(|s| {
        // Healthy worker: a real `join` client, so it can serve the
        // reassigned slot (`SlotAssign`) after its own.
        let ep2 = actual.clone();
        s.spawn(move || {
            let artifacts = sim_artifacts(DIM, 1, 64, 1).unwrap();
            let dataset = SimDataset { num_clients: NUM_CLIENTS };
            let client = SimDenseClient { dim: DIM, heavy: HEAVY };
            let opts =
                JoinOptions { read_timeout: Some(Duration::from_secs(20)), ..Default::default() };
            let sum = join(&ep2, &client, &dataset, &artifacts, &opts).unwrap();
            assert_eq!(sum.rounds, 1);
        });
        // Evil worker: corrupts its own upload's magic, then lingers.
        let ep2 = actual.clone();
        s.spawn(move || {
            let mut conn = dial(&ep2);
            let (seed, assignments) = start_round(&mut conn);
            let slot = assignments.first().map(|&(s, _)| s).unwrap_or(0);
            evil_corrupt_magic(&mut conn, slot, seed);
            let _ = read_msg(&mut conn, MAX_MSG);
        });
        let params = RoundParams {
            round: 0,
            round_seed: seed,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        srv.shutdown();
        stats
    });

    assert_eq!(stats.participants, 2, "both slots must arrive after reassignment");
    assert_eq!(stats.dropped_slots, 0);
    assert!(stats.retried_slots >= 1, "the corrupted slot must have been retried");

    // The reassigned slot's upload replaced the corrupt one cleanly:
    // weights equal the in-process reference over both clients.
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    let uploads: Vec<ClientUpload> = participants
        .iter()
        .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, seed)))
        .collect();
    run_server_round(&mut agg_ref, &sizes, uploads, &mut w_ref, LR).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w), "reassigned round diverged from the clean reference");
}

/// A peer speaking the wrong *transport* protocol version is dropped at
/// the handshake; a well-behaved pool still gets served.
#[test]
fn bad_handshake_is_dropped_and_round_proceeds() {
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 1,
        read_timeout: Duration::from_secs(10),
        accept_timeout: Duration::from_secs(20),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    std::thread::scope(|s| {
        let ep = actual.clone();
        s.spawn(move || {
            // Wrong protocol version: the server must reject us with an
            // abort (or a plain close)…
            wrong_version_hello(&ep, false);
            // …and then serve a well-behaved worker in its place.
            let artifacts = sim_artifacts(DIM, 1, 64, 1).unwrap();
            let dataset = SimDataset { num_clients: NUM_CLIENTS };
            let client = SimDenseClient { dim: DIM, heavy: HEAVY };
            let opts =
                JoinOptions { read_timeout: Some(Duration::from_secs(20)), ..Default::default() };
            let sum = join(&ep, &client, &dataset, &artifacts, &opts).unwrap();
            assert_eq!(sum.rounds, 1);
        });
        let participants = [3usize];
        let sizes = [1.0f32];
        let params = RoundParams {
            round: 0,
            round_seed: 11,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        assert_eq!(stats.losses.len(), 1);
        srv.shutdown();
    });
    assert!(w.iter().any(|&x| x != 0.0));
}

/// A hostile relay peer must cost exactly its own subtree: the sibling
/// subtree (a real `relay::Relay` over a real worker) survives, the
/// round closes at quorum with only the evil chain's slots dropped, and
/// the root stays reusable after a healthy relay replaces the dead one
/// — merged-frame fault attribution, end to end.
#[test]
fn relay_peer_faults_drop_only_their_subtree() {
    use fetchsgd::cohort::QuorumPolicy;
    use fetchsgd::compression::aggregate::run_server_round as reference_round;
    use fetchsgd::relay::{Relay, RelayOptions};

    let cases: Vec<(&str, fn(&mut Conn))> = vec![
        ("corrupt merged frame", evil_corrupt_merged),
        ("mid-merge disconnect", evil_vanish_mid_merge),
    ];

    for (name, evil) in cases {
        let ep = Endpoint::Tcp("127.0.0.1:0".into());
        let opts = ServeOptions {
            workers: 0,
            relay_children: 2,
            read_timeout: Duration::from_secs(10),
            accept_timeout: Duration::from_secs(20),
            // Half quorum: losing one of two subtrees must not kill the
            // round.
            quorum: QuorumPolicy::new(0.5, 0, 0).unwrap(),
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&ep, opts).unwrap();
        let actual = srv.local_endpoint().unwrap();
        let mut agg = UncompressedServer::new(DIM, 0.0);
        let mut w = vec![0f32; DIM];
        let participants = [0usize, 1, 2, 3];
        let sizes = [1.0f32; 4];
        let seed0 = round_seed(40);

        let w_partial = std::thread::scope(|s| {
            // The healthy subtree: a real relay over a real worker.
            let mut node = Relay::bind(
                &Endpoint::Tcp("127.0.0.1:0".into()),
                RelayOptions { workers: 1, ..Default::default() },
            )
            .unwrap();
            let down = node.local_endpoint().unwrap();
            let up = actual.clone();
            s.spawn(move || {
                node.run(&up).unwrap();
            });
            s.spawn(move || persistent_dense_worker(&down));
            // The hostile relay peer.
            let ep2 = actual.clone();
            s.spawn(move || {
                let mut conn = dial(&ep2);
                evil(&mut conn);
            });

            // Fault round: the evil chain drops, the healthy chain
            // lands, the round closes at quorum.
            let params = RoundParams {
                round: 0,
                round_seed: seed0,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv
                .run_round(&mut agg, &params, &mut w)
                .unwrap_or_else(|e| panic!("{name}: round must survive at quorum: {e:#}"));
            assert_eq!(stats.participants, 2, "{name}: only the evil chain may drop");
            assert_eq!(stats.dropped_slots, 2, "{name}: the whole evil chain must drop");
            assert_eq!(
                stats.losses.iter().filter(|&&l| l != 0.0).count(),
                2,
                "{name}: claimed outcomes from a corrupt reply must not be recorded"
            );
            assert_eq!(srv.connected(), 1, "{name}: the dead relay must be pruned");
            let w_partial = w.clone();

            // Recovery: a fresh healthy relay takes the dead one's
            // place; the same root serves a full round.
            let mut node = Relay::bind(
                &Endpoint::Tcp("127.0.0.1:0".into()),
                RelayOptions { workers: 1, ..Default::default() },
            )
            .unwrap();
            let down = node.local_endpoint().unwrap();
            let up = actual.clone();
            s.spawn(move || {
                node.run(&up).unwrap();
            });
            s.spawn(move || persistent_dense_worker(&down));
            let params = RoundParams {
                round: 1,
                round_seed: round_seed(41),
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv
                .run_round(&mut agg, &params, &mut w)
                .unwrap_or_else(|e| panic!("{name}: recovery round failed: {e:#}"));
            assert_eq!(stats.participants, 4, "{name}: recovery round must be full");
            srv.shutdown();
            w_partial
        });

        // Fingerprint the partial round: the surviving chain is either
        // {0,2} or {1,3} (the two relays race to connect), and the
        // weights must equal an in-process round over exactly that
        // membership — renormalized over the survivors, no trace of the
        // evil chain.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let reference = |survivors: [usize; 2]| {
            let mut w_ref = vec![0f32; DIM];
            let mut agg_ref = UncompressedServer::new(DIM, 0.0);
            let uploads: Vec<ClientUpload> = survivors
                .iter()
                .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, seed0)))
                .collect();
            reference_round(&mut agg_ref, &[1.0, 1.0], uploads, &mut w_ref, LR).unwrap();
            w_ref
        };
        let even = reference([0, 2]);
        let odd = reference([1, 3]);
        assert!(
            bits(&w_partial) == bits(&even) || bits(&w_partial) == bits(&odd),
            "{name}: partial weights match neither surviving chain's reference"
        );
    }
}

/// A relay peer speaking the wrong protocol version is dropped at the
/// handshake — same contract as a worker with a bad `Hello` — and a
/// healthy relay tier still gets served in its place.
#[test]
fn wrong_version_relay_hello_is_dropped_and_replaced() {
    use fetchsgd::compression::aggregate::run_server_round as reference_round;
    use fetchsgd::relay::{Relay, RelayOptions};

    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 0,
        relay_children: 1,
        read_timeout: Duration::from_secs(10),
        accept_timeout: Duration::from_secs(20),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let seed = round_seed(50);

    std::thread::scope(|s| {
        // Wrong-version relay hello: dialed first, so the root meets it
        // first (loopback accepts in connect order) and must reject it.
        let ep2 = actual.clone();
        s.spawn(move || wrong_version_hello(&ep2, true));
        // Give the bad peer's dial a head start before the healthy
        // relay goes up.
        std::thread::sleep(Duration::from_millis(200));
        let mut node = Relay::bind(
            &Endpoint::Tcp("127.0.0.1:0".into()),
            RelayOptions { workers: 1, ..Default::default() },
        )
        .unwrap();
        let down = node.local_endpoint().unwrap();
        let up = actual.clone();
        s.spawn(move || {
            node.run(&up).unwrap();
        });
        s.spawn(move || persistent_dense_worker(&down));

        let participants = [0usize, 1];
        let sizes = [1.0f32, 1.0];
        let params = RoundParams {
            round: 0,
            round_seed: seed,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        assert_eq!(stats.participants, 2, "the healthy relay must serve the full round");
        srv.shutdown();
    });

    // Single surviving tier, full round: deterministic reference.
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    let uploads: Vec<ClientUpload> =
        [0usize, 1].iter().map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, seed))).collect();
    reference_round(&mut agg_ref, &[1.0, 1.0], uploads, &mut w_ref, LR).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w), "round served through a relay diverged from the reference");
}

/// A `join` worker with a reconnect budget survives a round its server
/// had to abort (another worker's fault): the abort costs one
/// connection lifetime, the worker re-dials under backoff, and the same
/// `join` call serves the next round to completion.
#[test]
fn join_reconnects_after_a_faulted_round() {
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 2,
        read_timeout: Duration::from_secs(10),
        accept_timeout: Duration::from_secs(20),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants = [0usize, 1];
    let sizes = [1.0f32, 1.0];

    std::thread::scope(|s| {
        // The resilient worker: survives the aborted round and serves
        // the recovery round over a fresh connection.
        let ep2 = actual.clone();
        s.spawn(move || {
            let artifacts = sim_artifacts(DIM, 1, 64, 1).unwrap();
            let dataset = SimDataset { num_clients: NUM_CLIENTS };
            let client = SimDenseClient { dim: DIM, heavy: HEAVY };
            let opts = JoinOptions {
                read_timeout: Some(Duration::from_secs(20)),
                reconnect_attempts: 3,
                reconnect_backoff_ms: 50,
                ..Default::default()
            };
            let sum = join(&ep2, &client, &dataset, &artifacts, &opts).unwrap();
            assert_eq!(sum.rounds, 1, "only the recovery round completes");
        });
        // Fault round: an evil sibling truncates its frame, the server
        // aborts, both connections drop.
        let ep2 = actual.clone();
        s.spawn(move || {
            let mut conn = dial(&ep2);
            let (seed, assignments) = start_round(&mut conn);
            let slot = assignments.first().map(|&(s, _)| s).unwrap_or(0);
            evil_truncated_frame(&mut conn, slot, seed);
            let _ = read_msg(&mut conn, MAX_MSG);
        });
        let params = RoundParams {
            round: 0,
            round_seed: round_seed(60),
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        srv.run_round(&mut agg, &params, &mut w).unwrap_err();
        assert_eq!(srv.connected(), 0);

        // Recovery round: the reconnected join worker plus one fresh
        // single-round worker.
        let ep2 = actual.clone();
        s.spawn(move || good_worker(&ep2));
        let params = RoundParams {
            round: 1,
            round_seed: round_seed(61),
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        assert_eq!(stats.participants, 2, "the reconnected worker must serve the recovery round");
        srv.shutdown();
    });
}
