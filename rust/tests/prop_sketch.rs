//! Property-based tests of the Count-Sketch algebra and the FetchSGD
//! server invariants — pure Rust, no artifacts required.
//!
//! These pin the mathematical properties the paper's correctness rests
//! on: linearity (mergability), unbiasedness, heavy-hitter recovery,
//! and the equivalence claims of §3.2 (server-side vs client-side
//! momentum/error accumulation).

use fetchsgd::sketch::count_sketch::CountSketch;
use fetchsgd::sketch::topk::{top_k_sparse, SparseVec};
use fetchsgd::util::proptest::check;
use fetchsgd::util::stats::l2_norm;

const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xBEEF;

#[test]
fn prop_merge_is_commutative_and_associative() {
    check("merge comm/assoc", 30, |g| {
        let d = g.usize_in(10, 800);
        let a = g.vec_f32(d, d + 1, -2.0, 2.0);
        let b = g.vec_f32(d, d + 1, -2.0, 2.0);
        let c = g.vec_f32(d, d + 1, -2.0, 2.0);
        let s = |v: &[f32]| CountSketch::encode(ROWS, COLS, SEED, v).unwrap();
        // (a+b)+c == a+(b+c), a+b == b+a in sketch space
        let mut ab_c = s(&a);
        ab_c.add_scaled(&s(&b), 1.0);
        ab_c.add_scaled(&s(&c), 1.0);
        let mut a_bc = s(&c);
        a_bc.add_scaled(&s(&b), 1.0);
        a_bc.add_scaled(&s(&a), 1.0);
        for (x, y) in ab_c.table().iter().zip(a_bc.table()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    });
}

#[test]
fn prop_scale_distributes_over_encode() {
    check("scale linearity", 30, |g| {
        let d = g.usize_in(10, 500);
        let v = g.vec_f32(d, d + 1, -3.0, 3.0);
        let alpha = g.f32_in(-2.0, 2.0);
        let scaled: Vec<f32> = v.iter().map(|&x| alpha * x).collect();
        let mut s1 = CountSketch::encode(ROWS, COLS, SEED, &v).unwrap();
        s1.scale(alpha);
        let s2 = CountSketch::encode(ROWS, COLS, SEED, &scaled).unwrap();
        for (x, y) in s1.table().iter().zip(s2.table()) {
            assert!((x - y).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_server_side_equals_client_side_error_accumulation() {
    // §3.2's key linearity claim: accumulating error on the server in
    // one sketch == each client accumulating locally and uploading
    // sketches of the result.
    check("server == client accumulation", 20, |g| {
        let d = 400;
        let t_rounds = g.usize_in(2, 6);
        let w_clients = g.usize_in(1, 5);
        let grads: Vec<Vec<Vec<f32>>> = (0..t_rounds)
            .map(|_| (0..w_clients).map(|_| g.vec_f32(d, d + 1, -1.0, 1.0)).collect())
            .collect();
        // server-side: merge sketches per round, accumulate
        let mut server = CountSketch::zeros(ROWS, COLS, d, SEED).unwrap();
        for round in &grads {
            for gr in round {
                server.add_scaled(&CountSketch::encode(ROWS, COLS, SEED, gr).unwrap(), 1.0 / w_clients as f32);
            }
        }
        // client-side: each client sums its own gradients densely, then
        // sketches once at the end
        let mut client = CountSketch::zeros(ROWS, COLS, d, SEED).unwrap();
        for ci in 0..w_clients {
            let mut acc = vec![0f32; d];
            for round in &grads {
                for (a, &x) in acc.iter_mut().zip(&round[ci]) {
                    *a += x / w_clients as f32;
                }
            }
            client.add_scaled(&CountSketch::encode(ROWS, COLS, SEED, &acc).unwrap(), 1.0);
        }
        for (x, y) in server.table().iter().zip(client.table()) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    });
}

#[test]
fn prop_estimates_bounded_by_tail_noise() {
    // Count-Sketch guarantee: per-coordinate estimation error is
    // O(||tail|| / sqrt(cols)) w.h.p. — check a generous 5x bound.
    check("estimate error bound", 15, |g| {
        let d = 5000;
        let v = g.heavy_vec(d, 5, 20.0, 0.1);
        let s = CountSketch::encode(ROWS, 2048, g.u64(), &v).unwrap();
        let bound = 5.0 * l2_norm(&v) / (2048f64).sqrt();
        let mut violations = 0;
        for i in (0..d).step_by(37) {
            let err = (s.estimate(i as u32) - v[i]).abs() as f64;
            if err > bound {
                violations += 1;
            }
        }
        assert!(violations <= 1, "{violations} estimates exceeded 5x tail bound {bound}");
    });
}

#[test]
fn prop_topk_of_unsketch_matches_true_topk_for_separated_vectors() {
    check("topk recovery", 15, |g| {
        let d = g.usize_in(2000, 10_000);
        let k = g.usize_in(1, 6);
        // plant k well-separated heavy coords over small noise
        let mut v = g.heavy_vec(d, 0, 0.0, 0.02);
        let mut planted = Vec::new();
        for j in 0..k {
            let mut i = g.usize_in(0, d);
            while planted.contains(&i) {
                i = g.usize_in(0, d);
            }
            planted.push(i);
            v[i] = 30.0 * (j + 1) as f32 * if g.bool() { 1.0 } else { -1.0 };
        }
        let s = CountSketch::encode(ROWS, 4096, g.u64(), &v).unwrap();
        let mut got = s.top_k(k).idx;
        got.sort();
        let mut want: Vec<u32> = planted.iter().map(|&i| i as u32).collect();
        want.sort();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_zero_out_is_idempotent() {
    check("zero_out idempotent", 20, |g| {
        let d = 600;
        let v = g.vec_f32(d, d + 1, -2.0, 2.0);
        let mut s = CountSketch::encode(ROWS, COLS, SEED, &v).unwrap();
        let delta = s.top_k(g.usize_in(1, 20));
        s.zero_out_sparse(&delta);
        let t1 = s.table().to_vec();
        s.zero_out_sparse(&delta);
        assert_eq!(t1, s.table());
    });
}

#[test]
fn prop_sparse_topk_upload_roundtrip() {
    // local top-k wire format: dense -> topk sparse -> dense preserves
    // exactly the k largest entries and zeroes the rest.
    check("topk wire roundtrip", 30, |g| {
        let d = g.usize_in(5, 400);
        let v = g.vec_f32(d, d + 1, -10.0, 10.0);
        let k = g.usize_in(1, d + 1);
        let sv = top_k_sparse(&v, k);
        let dense = sv.to_dense();
        let kept: Vec<usize> = (0..d).filter(|&i| dense[i] != 0.0).collect();
        assert!(kept.len() <= k);
        for &i in &kept {
            assert_eq!(dense[i], v[i]);
        }
        // every kept magnitude >= every dropped magnitude
        let min_kept = kept.iter().map(|&i| v[i].abs()).fold(f32::INFINITY, f32::min);
        for i in 0..d {
            if dense[i] == 0.0 && v[i] != 0.0 && !kept.contains(&i) {
                assert!(v[i].abs() <= min_kept + 1e-6);
            }
        }
    });
}

#[test]
fn prop_sparsevec_add_into_matches_dense_addition() {
    check("sparse add_into", 30, |g| {
        let d = g.usize_in(5, 300);
        let base = g.vec_f32(d, d + 1, -1.0, 1.0);
        let v = g.vec_f32(d, d + 1, -1.0, 1.0);
        let k = g.usize_in(1, d + 1);
        let sv = top_k_sparse(&v, k);
        let scale = g.f32_in(-2.0, 2.0);
        let mut got = base.clone();
        sv.add_into(&mut got, scale);
        let sd = sv.to_dense();
        for i in 0..d {
            let want = base[i] + scale * sd[i];
            assert!((got[i] - want).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_merged_sketch_estimates_mean_gradient() {
    // End-to-end server aggregation property: estimates from the merged
    // sketch approximate coordinates of the *mean* gradient.
    check("merged estimates mean", 10, |g| {
        let d = 3000;
        let w = g.usize_in(2, 6);
        let heavy_coord = g.usize_in(0, d);
        let mut mean = vec![0f32; d];
        let mut agg = CountSketch::zeros(ROWS, 4096, d, SEED).unwrap();
        for _ in 0..w {
            let mut gr = g.heavy_vec(d, 0, 0.0, 0.05);
            gr[heavy_coord] += 8.0;
            for (m, &x) in mean.iter_mut().zip(&gr) {
                *m += x / w as f32;
            }
            agg.add_scaled(&CountSketch::encode(ROWS, 4096, SEED, &gr).unwrap(), 1.0 / w as f32);
        }
        let est = agg.estimate(heavy_coord as u32);
        assert!(
            (est - mean[heavy_coord]).abs() < 0.5,
            "est {est} vs mean {}",
            mean[heavy_coord]
        );
    });
}

#[test]
fn prop_sparsevec_from_pairs_sorts() {
    check("from_pairs sorted", 30, |g| {
        let d = 1000;
        let n = g.usize_in(1, 50);
        let mut used = std::collections::HashSet::new();
        let mut pairs = Vec::new();
        for _ in 0..n {
            let i = g.usize_in(0, d) as u32;
            if used.insert(i) {
                pairs.push((i, g.f32_in(-1.0, 1.0)));
            }
        }
        let sv = SparseVec::from_pairs(d, pairs);
        assert!(sv.idx.windows(2).all(|w| w[0] < w[1]));
    });
}

#[test]
fn prop_any_row_strip_partition_equals_whole_sketch_merge() {
    // The row-strip fan-in contract behind `aggregate::RoundPipeline`'s
    // parallel reduction: folding each shard's rows strip by strip (any
    // partition of the row range, strips outer / shards inner) performs
    // the same per-cell additions in the same order as the whole-table
    // merge, so the result is *bitwise* identical — not approximately.
    check("strip partition == whole merge", 20, |g| {
        let d = g.usize_in(100, 2000);
        let nshards = g.usize_in(1, 5);
        let shards: Vec<CountSketch> = (0..nshards)
            .map(|_| {
                let v = g.vec_f32(d, d + 1, -2.0, 2.0);
                CountSketch::encode(ROWS, COLS, SEED, &v).unwrap()
            })
            .collect();
        let mut whole = CountSketch::zeros(ROWS, COLS, d, SEED).unwrap();
        let mut striped = CountSketch::zeros(ROWS, COLS, d, SEED).unwrap();
        for s in &shards {
            whole.add_scaled(s, 1.0);
        }
        // A random partition of 0..ROWS into contiguous strips.
        let mut cuts = vec![0usize, ROWS];
        for _ in 0..g.usize_in(0, ROWS) {
            cuts.push(g.usize_in(1, ROWS));
        }
        cuts.sort_unstable();
        cuts.dedup();
        for win in cuts.windows(2) {
            for s in &shards {
                striped.add_scaled_rows(s, 1.0, win[0]..win[1]);
            }
        }
        for (a, b) in whole.table().iter().zip(striped.table()) {
            assert_eq!(a.to_bits(), b.to_bits(), "strips {cuts:?} diverged from whole merge");
        }
    });
}

#[test]
fn prop_strip_parallel_shard_reduce_is_bitwise_equal_to_sequential() {
    // End-to-end through `aggregate::reduce_shards_in_place`: the
    // row-strip-parallel reduction must be bitwise identical to the
    // sequential fan-in at any worker count, for sketch and dense shard
    // kinds. Tables are sized past the parallel-reduce gate so the
    // striped code path actually runs.
    use fetchsgd::compression::aggregate::{reduce_shards_in_place, RoundAccum};
    use fetchsgd::compression::{ClientUpload, UploadSpec};
    check("reduce parallelism invariance", 6, |g| {
        // Sketch shards: 5x16384 = 81920 cells.
        let d = g.usize_in(500, 3000);
        let cols = 16384usize;
        let spec = UploadSpec::Sketch { rows: ROWS, cols, dim: d, seed: SEED };
        let n = g.usize_in(2, 5);
        let sketches: Vec<CountSketch> = (0..n)
            .map(|_| {
                let v = g.vec_f32(d, d + 1, -2.0, 2.0);
                CountSketch::encode(ROWS, cols, SEED, &v).unwrap()
            })
            .collect();
        let build = |sketches: &[CountSketch]| -> Vec<RoundAccum> {
            sketches
                .iter()
                .map(|s| {
                    let mut a = RoundAccum::new(&spec).unwrap();
                    a.absorb(ClientUpload::Sketch(s.clone()), 0.5).unwrap();
                    a
                })
                .collect()
        };
        let mut seq = build(&sketches);
        reduce_shards_in_place(&mut seq, 1).unwrap();
        for par in [2usize, 5, 9] {
            let mut p = build(&sketches);
            reduce_shards_in_place(&mut p, par).unwrap();
            assert_eq!(p[0].absorbed(), seq[0].absorbed());
            for (a, b) in
                seq[0].as_sketch().unwrap().table().iter().zip(p[0].as_sketch().unwrap().table())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "sketch reduce diverged at par={par}");
            }
        }

        // Dense shards, past the gate too.
        let dim = 70_000 + g.usize_in(0, 5000);
        let dspec = UploadSpec::Dense { dim };
        let vecs: Vec<Vec<f32>> = (0..2).map(|_| g.vec_f32(dim, dim + 1, -1.0, 1.0)).collect();
        let build_dense = |vecs: &[Vec<f32>]| -> Vec<RoundAccum> {
            vecs.iter()
                .map(|v| {
                    let mut a = RoundAccum::new(&dspec).unwrap();
                    a.absorb(ClientUpload::Dense(v.clone()), 0.25).unwrap();
                    a
                })
                .collect()
        };
        let mut seq = build_dense(&vecs);
        reduce_shards_in_place(&mut seq, 1).unwrap();
        let mut par = build_dense(&vecs);
        reduce_shards_in_place(&mut par, 7).unwrap();
        for (a, b) in seq[0].as_dense().unwrap().iter().zip(par[0].as_dense().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits(), "dense reduce diverged");
        }
    });
}

#[test]
fn prop_blocked_kernels_match_scalar_reference_bitwise() {
    // PR 6's cache-blocked kernels (`util::kernels`, the LE byte walk,
    // and `add_scaled_rows` on top of them) must perform the same
    // per-cell operation in the same order as the scalar loops they
    // replaced — bitwise, over lengths that land on, under, and past
    // the 8-lane block boundary, and over random sketch geometries.
    use fetchsgd::serialize::le::{axpy_f32_le, extend_f32_le};
    use fetchsgd::util::kernels;
    check("blocked kernels == scalar", 30, |g| {
        let n = g.usize_in(1, 700);
        let src = g.vec_f32(n, n + 1, -3.0, 3.0);
        let base = g.vec_f32(n, n + 1, -3.0, 3.0);
        let scale = g.f32_in(-2.0, 2.0);

        // axpy: dst[i] += scale * src[i]
        let mut got = base.clone();
        kernels::axpy(&mut got, &src, scale);
        for i in 0..n {
            let want = base[i] + scale * src[i];
            assert_eq!(got[i].to_bits(), want.to_bits(), "axpy diverged at {i} (n={n})");
        }

        // add: dst[i] += src[i] (its own kernel, not axpy(scale=1))
        let mut got = base.clone();
        kernels::add(&mut got, &src);
        for i in 0..n {
            let want = base[i] + src[i];
            assert_eq!(got[i].to_bits(), want.to_bits(), "add diverged at {i} (n={n})");
        }

        // the blocked LE byte walk: dst[i] += w * decode(bytes[4i..])
        let mut bytes = Vec::new();
        extend_f32_le(&mut bytes, &src);
        let mut got = base.clone();
        axpy_f32_le(&bytes, scale, &mut got);
        for i in 0..n {
            let want = base[i] + scale * src[i];
            assert_eq!(got[i].to_bits(), want.to_bits(), "le axpy diverged at {i} (n={n})");
        }

        // add_scaled_rows over a random geometry rides the same kernel.
        let rows = g.usize_in(1, 9);
        let cols = 1usize << g.usize_in(5, 12);
        let d = g.usize_in(10, 400);
        let a = g.vec_f32(d, d + 1, -2.0, 2.0);
        let b = g.vec_f32(d, d + 1, -2.0, 2.0);
        let dst0 = CountSketch::encode(rows, cols, SEED, &a).unwrap();
        let sb = CountSketch::encode(rows, cols, SEED, &b).unwrap();
        let mut blocked = dst0.clone();
        blocked.add_scaled_rows(&sb, scale, 0..rows);
        for (i, ((&acc, &x), &y)) in
            dst0.table().iter().zip(sb.table()).zip(blocked.table()).enumerate()
        {
            let want = acc + scale * x;
            assert_eq!(want.to_bits(), y.to_bits(), "add_scaled_rows diverged at cell {i}");
        }
    });
}

#[test]
fn prop_simd_dispatch_matches_scalar_twin_bitwise() {
    // PR 9's SIMD layer (`util::simd`): whichever implementation the
    // `simd` feature selects, every kernel entry point must produce the
    // exact bits of its always-compiled scalar twin — over odd lengths,
    // remainder tails, and slices starting at every sub-block offset
    // (the SSE2 path uses unaligned loads, so a slice that starts 1..3
    // elements into an allocation must not change anything). With the
    // feature off this pins dispatch == scalar; with it on it is the
    // whole bitwise-determinism claim.
    use fetchsgd::serialize::le::extend_f32_le;
    use fetchsgd::util::simd::{self, scalar};
    use fetchsgd::wire::codec::f32_to_f16_bits;
    check("simd dispatch == scalar twin", 40, |g| {
        let n = g.usize_in(1, 300);
        let off = g.usize_in(0, 4);
        let src = g.vec_f32(n + off, n + off + 1, -3.0, 3.0);
        let base = g.vec_f32(n + off, n + off + 1, -3.0, 3.0);
        let w = g.f32_in(-2.0, 2.0);

        // axpy / add / scale on the offset slices.
        let (mut got, mut want) = (base.clone(), base.clone());
        simd::axpy(&mut got[off..], &src[off..], w);
        scalar::axpy(&mut want[off..], &src[off..], w);
        assert_bits(&got, &want, "axpy", n, off);
        let (mut got, mut want) = (base.clone(), base.clone());
        simd::add(&mut got[off..], &src[off..]);
        scalar::add(&mut want[off..], &src[off..]);
        assert_bits(&got, &want, "add", n, off);
        let (mut got, mut want) = (base.clone(), base.clone());
        simd::scale(&mut got[off..], w);
        scalar::scale(&mut want[off..], w);
        assert_bits(&got, &want, "scale", n, off);

        // The LE byte walks, through a byte slice that itself starts at
        // an arbitrary (odd-capable) byte offset into its allocation.
        let boff = g.usize_in(0, 5);
        let mut bytes = vec![0xA5u8; boff];
        extend_f32_le(&mut bytes, &src[off..]);
        let (mut got, mut want) = (base.clone(), base.clone());
        simd::axpy_f32_le(&bytes[boff..], w, &mut got[off..]);
        scalar::axpy_f32_le(&bytes[boff..], w, &mut want[off..]);
        assert_bits(&got, &want, "axpy_f32_le", n, off);

        // f16le: quantize the same values, planting the awkward
        // classes (±inf, NaN, sub-normals, -0.0) so the widening path
        // is exercised well past the normal range.
        let mut hbytes = vec![0x5Au8; boff];
        for (i, &x) in src[off..].iter().enumerate() {
            let h = match i % 7 {
                0 => 0x7C00,              // +inf
                1 => 0xFC00,              // -inf
                2 => 0x7E01,              // NaN
                3 => 0x0001,              // smallest subnormal
                4 => 0x03FF,              // largest subnormal
                5 => 0x8000,              // -0.0
                _ => f32_to_f16_bits(x),
            };
            hbytes.extend_from_slice(&h.to_le_bytes());
        }
        let (mut got, mut want) = (base.clone(), base.clone());
        simd::axpy_f16_le(&hbytes[boff..], w, &mut got[off..]);
        scalar::axpy_f16_le(&hbytes[boff..], w, &mut want[off..]);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            // NaN lanes: same payload bits either way is the contract.
            assert_eq!(a.to_bits(), b.to_bits(), "axpy_f16_le diverged at {i} (n={n} off={off})");
        }

        // Encode hashing, dense and sparse, with planted ±0.0 entries
        // (the zero-skip must stay bitwise-neutral).
        use fetchsgd::hashing::SketchHasher;
        let cols = 1usize << g.usize_in(4, 11);
        let shift = 32 - cols.trailing_zeros();
        let hasher = SketchHasher::new(1, cols, g.u64()).unwrap();
        let h = hasher.row(0);
        let mut gvec = g.vec_f32(n, n + 1, -2.0, 2.0);
        gvec[g.usize_in(0, n)] = 0.0;
        gvec[g.usize_in(0, n)] = -0.0;
        let row0 = g.vec_f32(cols, cols + 1, -1.0, 1.0);
        let (mut got, mut want) = (row0.clone(), row0.clone());
        simd::accumulate_row(&mut got, h, shift, &gvec, w);
        scalar::accumulate_row(&mut want, h, shift, &gvec, w);
        assert_bits(&got, &want, "accumulate_row", n, off);
        let stride = g.usize_in(1, 5) as u32;
        let idx: Vec<u32> = (0..n as u32).map(|i| i * stride).collect();
        let (mut got, mut want) = (row0.clone(), row0.clone());
        simd::accumulate_row_sparse(&mut got, h, shift, &idx, &gvec, w);
        scalar::accumulate_row_sparse(&mut want, h, shift, &idx, &gvec, w);
        assert_bits(&got, &want, "accumulate_row_sparse", n, off);
    });
}

fn assert_bits(got: &[f32], want: &[f32], what: &str, n: usize, off: usize) {
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what} diverged at {i} (n={n} off={off})");
    }
}

#[test]
fn prop_hoisted_sparse_accumulate_matches_per_element_reference() {
    // PR 9 reworked `CountSketch::accumulate_sparse` from per-(row,
    // element) `bucket_sign` calls to the hoisted per-row form dense
    // absorption already used. The rework must be invisible: the same
    // bits as the historical fold `table[r][bucket] += sign * v *
    // scale`, including planted exact zeros (skipped now, absorbed as
    // `±0.0 * scale` before — both add nothing to any reachable
    // accumulator value).
    check("sparse accumulate == bucket_sign reference", 25, |g| {
        let d = g.usize_in(10, 500);
        let n = g.usize_in(1, d.min(60) + 1);
        let mut used = std::collections::HashSet::new();
        let mut pairs = Vec::new();
        for _ in 0..n {
            let i = g.usize_in(0, d) as u32;
            if used.insert(i) {
                // A mix of ordinary values and planted ±0.0.
                let v = match pairs.len() % 5 {
                    3 => 0.0,
                    4 => -0.0,
                    _ => g.f32_in(-2.0, 2.0),
                };
                pairs.push((i, v));
            }
        }
        let sv = SparseVec::from_pairs(d, pairs);
        let scale = g.f32_in(-2.0, 2.0);
        let base = g.vec_f32(d, d + 1, -1.0, 1.0);
        let mut s = CountSketch::encode(ROWS, COLS, SEED, &base).unwrap();
        let mut reference = s.table().to_vec();
        let (rows, cols) = (s.rows(), s.cols());
        // Historical per-element fold, verbatim.
        for r in 0..rows {
            for (j, &i) in sv.idx.iter().enumerate() {
                let (b, sgn) = s.hasher().bucket_sign(r, i);
                reference[r * cols + b] += sgn * sv.val[j] * scale;
            }
        }
        s.accumulate_sparse(&sv, scale);
        for (a, b) in s.table().iter().zip(&reference) {
            assert_eq!(a.to_bits(), b.to_bits(), "hoisted sparse accumulate diverged");
        }
    });
}

#[test]
fn prop_sharded_lock_absorb_matches_sequential_reduce() {
    // The per-shard-lock stress test: many workers offering frames in
    // an adversarial (shuffled) arrival order through the lock-free
    // claim layer and per-shard mutexes must finish to bits identical
    // to a single thread offering every slot in order.
    use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
    use fetchsgd::compression::{ClientUpload, UploadSpec};
    use fetchsgd::wire::{encode_upload, F32LE};
    use std::sync::atomic::{AtomicUsize, Ordering};
    check("sharded-lock absorb == sequential", 10, |g| {
        let d = g.usize_in(50, 400);
        let slots = g.usize_in(2, 40);
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: d, seed: SEED };
        let frames: Vec<Vec<u8>> = (0..slots)
            .map(|_| {
                let v = g.vec_f32(d, d + 1, -2.0, 2.0);
                let s = CountSketch::encode(ROWS, COLS, SEED, &v).unwrap();
                encode_upload(&ClientUpload::Sketch(s), &F32LE)
            })
            .collect();
        let weights: Vec<f32> = (0..slots).map(|_| g.f32_in(0.1, 1.0)).collect();

        // Sequential reference: one thread, slot order.
        let mut pl = RoundPipeline::new(PipelineOptions::default());
        let seq = pl.begin(&spec, weights.clone()).unwrap();
        for (slot, f) in frames.iter().enumerate() {
            seq.offer_frame(slot, f.clone()).unwrap();
        }
        let seq = pl.finish(seq).unwrap();

        // Adversarial order: Fisher-Yates shuffle of the slots, eight
        // workers racing to pull the next shuffled slot and offer its
        // frame zero-copy.
        let mut order: Vec<usize> = (0..slots).collect();
        for i in (1..slots).rev() {
            order.swap(i, g.usize_in(0, i + 1));
        }
        let mut pl2 = RoundPipeline::new(PipelineOptions::default());
        let round = pl2.begin(&spec, weights).unwrap();
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::SeqCst);
                    if i >= order.len() {
                        break;
                    }
                    let slot = order[i];
                    round.offer_frame_bytes(slot, &frames[slot]).unwrap();
                });
            }
        });
        assert!(round.is_complete());
        let par = pl2.finish(round).unwrap();

        for (a, b) in seq.as_sketch().unwrap().table().iter().zip(par.as_sketch().unwrap().table())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "sharded-lock absorb diverged (slots={slots})");
        }
    });
}
