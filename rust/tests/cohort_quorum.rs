//! Cohort membership acceptance tests: quorum rounds, slot
//! retry/reassignment, and participation-aware aggregation.
//!
//! The contract under test: *which* slots drop may depend on faults and
//! wall-clock, but conditioned on the final membership set the round's
//! renormalized merge is a pure function of that set — bitwise
//! identical across parallelism {1, 3, 8} in-process, and across the
//! process boundary (a served run over UDS/TCP vs the in-process
//! engine ending with the same surviving membership).

use std::collections::BTreeSet;
use std::io::Write;
use std::sync::mpsc;
use std::time::Duration;

use fetchsgd::cohort::{QuorumPolicy, SlotOutcome};
use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{
    sim_artifacts, synth_grad, SimDataset, SimFlakyClient, SimSketchClient,
};
use fetchsgd::compression::{ClientUpload, ServerAggregator};
use fetchsgd::coordinator::{engine, ClientSelector};
use fetchsgd::metrics::{MetricsLogger, RoundRecord};
use fetchsgd::sketch::CountSketch;
use fetchsgd::transport::framing::{read_msg, write_msg};
use fetchsgd::transport::proto::{Msg, PROTO_VERSION};
use fetchsgd::transport::{Conn, Endpoint, RoundParams, RoundServer, ServeOptions};
use fetchsgd::util::rng::derive_seed;
use fetchsgd::wire::{encode_upload, F32LE};

const DIM: usize = 20_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xC0;
const HEAVY: usize = 4;
const LR: f32 = 0.05;
const MAX_MSG: usize = 64 << 20;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn make_server() -> FetchSgdServer {
    FetchSgdServer::new(ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
        .unwrap()
}

/// Multi-round in-process loop with a flaky client under a quorum
/// policy: returns (final weights, per-round membership fingerprints).
fn flaky_train(
    fail: &BTreeSet<usize>,
    policy: &QuorumPolicy,
    threads: usize,
    rounds: usize,
    cohort: usize,
) -> (Vec<f32>, Vec<(Vec<usize>, usize, usize)>) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(200, cohort, SEED);
    let client = SimFlakyClient {
        inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: HEAVY },
        fail: fail.clone(),
    };
    let mut server = make_server();
    let mut w = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut memberships = Vec::new();
    for round in 0..rounds {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: LR,
            round_seed: derive_seed(SEED, round as u64),
            threads,
            wire: None,
            policy,
            round: round as u64,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        let s = out.membership.summary();
        memberships.push((out.membership.arrived_slots(), s.dropped_slots, s.retried_slots));
        let update = server.finish(&out.merged, LR).unwrap();
        pipeline.recycle(out.merged);
        update.apply(&mut w);
    }
    (w, memberships)
}

/// Same final membership set ⇒ bitwise-identical weights at
/// parallelism {1, 3, 8}, across a multi-round run where every round
/// drops the flaky subset and renormalizes over the survivors.
#[test]
fn quorum_rounds_are_bitwise_identical_across_parallelism() {
    // ~14% of the population always faults.
    let fail: BTreeSet<usize> = (0..200).filter(|c| c % 7 == 0).collect();
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let (w1, m1) = flaky_train(&fail, &policy, 1, 3, 24);
    assert!(w1.iter().any(|&x| x != 0.0), "training must move the model");
    assert!(
        m1.iter().any(|(_, dropped, _)| *dropped > 0),
        "the flaky subset must actually drop slots"
    );
    for threads in [3usize, 8] {
        let (wn, mn) = flaky_train(&fail, &policy, threads, 3, 24);
        assert_eq!(m1, mn, "membership history diverged at {threads} threads");
        assert_eq!(bits(&w1), bits(&wn), "weights diverged at {threads} threads");
    }
}

/// A hand-rolled worker's role sheet, keyed on the *client ids* it is
/// assigned — never on spawn or accept order, which the listener does
/// not guarantee. Every worker of a test gets the same sheet, so
/// whichever connection draws the marked client acts the part.
struct Roles {
    /// Disconnect mid-upload (forged length prefix, partial body) when
    /// reaching this client id's slot.
    disconnect_on: Option<u32>,
    /// Withhold this client id's upload until the gate releases (a
    /// straggler); tolerate every error afterwards.
    straggle_on: Option<u32>,
    gate: Option<mpsc::Receiver<()>>,
}

impl Roles {
    fn good() -> Roles {
        Roles { disconnect_on: None, straggle_on: None, gate: None }
    }
}

/// One hand-rolled transport worker: mirrors `SimSketchClient` exactly
/// (synthetic gradient → client-side sketch) so served uploads are
/// bit-identical to in-process ones. Uploads every assigned slot, then
/// serves `SlotAssign` reassignments until shutdown.
fn worker(ep: &Endpoint, roles: Roles) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(&mut conn, MAX_MSG).unwrap();
    let (seed, assignments) = match Msg::decode(bytes).unwrap() {
        Msg::RoundStart { round_seed, assignments, .. } => (round_seed, assignments),
        _ => panic!("expected round-start"),
    };
    let upload = |conn: &mut Conn, slot: u32, client: u32| -> anyhow::Result<u64> {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let sketch = CountSketch::encode(ROWS, COLS, SEED, &g).unwrap();
        let frame = encode_upload(&ClientUpload::Sketch(sketch), &F32LE);
        write_msg(conn, &Msg::Upload { slot, loss: 0.5, frame }.encode())
    };
    for &(slot, client) in &assignments {
        if roles.disconnect_on == Some(client) {
            // Claim a 4096-byte message, deliver 10 bytes, vanish —
            // the mid-upload disconnect of the acceptance scenario.
            conn.write_all(&4096u32.to_le_bytes()).unwrap();
            conn.write_all(&[7u8; 10]).unwrap();
            conn.flush().unwrap();
            conn.shutdown();
            return;
        }
        if roles.straggle_on == Some(client) {
            // Straggler: the server drops us at the round deadline;
            // everything after the gate is best-effort.
            if let Some(rx) = &roles.gate {
                let _ = rx.recv_timeout(Duration::from_secs(30));
            }
            let _ = upload(&mut conn, slot, client);
            return;
        }
        upload(&mut conn, slot, client).unwrap();
    }
    // Serve reassignments until the server says we're done.
    loop {
        let Ok((bytes, _)) = read_msg(&mut conn, MAX_MSG) else { return };
        match Msg::decode(bytes) {
            Ok(Msg::SlotAssign { slot, client }) => {
                upload(&mut conn, slot, client).unwrap();
            }
            Ok(Msg::RoundEnd { .. }) => {}
            _ => return,
        }
    }
}

/// A served round on a real socket with retries=0: a worker that
/// disconnects drops exactly its slot, the round closes at quorum, and
/// the weights are bitwise identical to the in-process engine ending
/// with the same surviving membership — at parallelism 1, 3, and 8.
#[cfg(unix)]
#[test]
fn uds_dropped_slot_matches_in_process_membership() {
    let path = std::env::temp_dir().join(format!("fsgw_cq_{}.sock", std::process::id()));
    let ep = Endpoint::Unix(path);
    let opts = ServeOptions {
        workers: 4,
        read_timeout: Duration::from_secs(20),
        accept_timeout: Duration::from_secs(20),
        quorum: QuorumPolicy::new(0.5, 0, 0).unwrap(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = make_server();
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = vec![0, 1, 2, 3];
    let sizes = vec![1.0f32; 4];
    let round_seed = derive_seed(SEED, 0);

    std::thread::scope(|s| {
        // Every worker carries the same role sheet — whichever
        // connection draws client 2's slot vanishes mid-upload.
        for _ in 0..4 {
            let ep = actual.clone();
            s.spawn(move || {
                worker(
                    &ep,
                    Roles { disconnect_on: Some(2), straggle_on: None, gate: None },
                )
            });
        }
        let params = RoundParams {
            round: 0,
            round_seed,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        assert_eq!(stats.participants, 3, "client 2's slot must drop");
        assert_eq!(stats.dropped_slots, 1);
        assert_eq!(stats.retried_slots, 0, "no retry budget configured");
        srv.shutdown();
    });

    // In-process engine over the same surviving membership set (client
    // 2 faults deterministically), at several parallelism levels.
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let flaky = SimFlakyClient {
        inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: HEAVY },
        fail: [2usize].into_iter().collect(),
    };
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let w0 = vec![0f32; DIM];
    for threads in [1usize, 3, 8] {
        let mut server = make_server();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &flaky,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w0,
            lr: LR,
            round_seed,
            threads,
            wire: None,
            policy: &policy,
            round: 0,
            trace: None,
        };
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        assert_eq!(out.membership.arrived_slots(), vec![0, 1, 3]);
        let update = server.finish(&out.merged, LR).unwrap();
        let mut w_ref = vec![0f32; DIM];
        update.apply(&mut w_ref);
        assert_eq!(
            bits(&w),
            bits(&w_ref),
            "served partial round diverges from in-process (threads {threads})"
        );
    }
}

/// The issue's acceptance scenario, end to end: one worker disconnects
/// mid-upload (slot reassigned to a healthy connection — `Retried`),
/// one straggler holds its upload past the round deadline (`Dropped`),
/// and the round still completes at `quorum_fraction = 0.5` with
/// renormalized weights bitwise identical to an in-process run over
/// the same surviving membership set — with the dropped/retried slots
/// visible in JSONL metrics.
#[test]
fn disconnect_and_straggler_round_completes_at_quorum() {
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 4,
        read_timeout: Duration::from_secs(20),
        accept_timeout: Duration::from_secs(20),
        quorum: QuorumPolicy::new(0.5, 2500, 1).unwrap(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = make_server();
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = vec![0, 1, 2, 3];
    let sizes = vec![1.0f32; 4];
    let round_seed = derive_seed(SEED, 9);

    let stats = std::thread::scope(|s| {
        // Every worker carries the same role sheet, keyed on the
        // assignment (accept order is not deterministic): the
        // connection that draws client 1 disconnects mid-upload; the
        // one that draws client 3 straggles past the deadline; the
        // rest are good and serve the reassignment. Each worker gets
        // its own gate; only the actual straggler ever waits on one.
        let mut senders = Vec::new();
        for _ in 0..4 {
            let (tx, rx) = mpsc::channel();
            senders.push(tx);
            let ep = actual.clone();
            let roles =
                Roles { disconnect_on: Some(1), straggle_on: Some(3), gate: Some(rx) };
            s.spawn(move || worker(&ep, roles));
        }
        let params = RoundParams {
            round: 0,
            round_seed,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        srv.shutdown();
        // Release the straggler only after the round closed without it.
        for tx in senders {
            let _ = tx.send(());
        }
        stats
    });

    assert_eq!(stats.participants, 3, "disconnected slot retried, straggler dropped");
    assert_eq!(stats.dropped_slots, 1);
    assert_eq!(stats.retried_slots, 1);

    // JSONL metrics make the membership visible.
    let dir = std::env::temp_dir().join(format!("fsgd_cq_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let log = dir.join("quorum.jsonl");
    {
        let mut logger = MetricsLogger::new(Some(&log)).unwrap();
        let n = stats.participants as u64;
        logger.log_round(RoundRecord {
            round: 0,
            loss: stats.mean_loss,
            lr: LR as f64,
            upload_bytes: stats.upload_bytes_per_client * n,
            download_bytes: stats.download_bytes_per_client * n,
            wire_upload_bytes: stats.wire_upload_bytes_per_client * n,
            wire_download_bytes: stats.wire_download_bytes_per_client * n,
            transport_bytes: stats.transport_bytes,
            absorb_stalls: stats.absorb_stalls,
            parked_bytes: stats.parked_bytes,
            chosen_shards: stats.chosen_shards as usize,
            participants: stats.participants,
            dropped_slots: stats.dropped_slots,
            retried_slots: stats.retried_slots,
            update_nnz: stats.update_nnz,
            round_ms: stats.timing.round_ms,
            compute_ms: stats.timing.compute_ms,
            absorb_ms: stats.timing.absorb_ms,
            reduce_ms: stats.timing.reduce_ms,
            tier: None,
        });
    }
    let text = std::fs::read_to_string(&log).unwrap();
    let v = fetchsgd::serialize::json::parse(text.lines().next().unwrap()).unwrap();
    assert!((v.req_f64("participants").unwrap() - 3.0).abs() < 1e-9);
    assert!((v.req_f64("dropped_slots").unwrap() - 1.0).abs() < 1e-9);
    assert!((v.req_f64("retried_slots").unwrap() - 1.0).abs() < 1e-9);
    std::fs::remove_dir_all(&dir).ok();

    // In-process reference over the same surviving membership (client
    // 3 faults; clients 0, 1, 2 arrive): bitwise-identical weights.
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let flaky = SimFlakyClient {
        inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: HEAVY },
        fail: [3usize].into_iter().collect(),
    };
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let mut server = make_server();
    let weights = server.begin_round(&sizes);
    let w0 = vec![0f32; DIM];
    let ctx = engine::RoundCtx {
        client: &flaky,
        artifacts: &artifacts,
        dataset: &dataset,
        w: &w0,
        lr: LR,
        round_seed,
        threads: 4,
        wire: None,
        policy: &policy,
        round: 0,
        trace: None,
    };
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let out = engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
        .unwrap();
    assert_eq!(out.membership.arrived_slots(), vec![0, 1, 2]);
    assert!(matches!(out.membership.outcome(3), SlotOutcome::Dropped(_)));
    let update = server.finish(&out.merged, LR).unwrap();
    let mut w_ref = vec![0f32; DIM];
    update.apply(&mut w_ref);
    assert_eq!(
        bits(&w),
        bits(&w_ref),
        "retry + straggler round diverges from the in-process engine on the same membership"
    );
}

/// Below the quorum the served round still fails loudly (and the
/// server stays reusable), exactly like the strict pre-cohort path.
#[test]
fn unmet_quorum_fails_the_round_loudly() {
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let opts = ServeOptions {
        workers: 2,
        read_timeout: Duration::from_secs(20),
        accept_timeout: Duration::from_secs(20),
        quorum: QuorumPolicy::new(0.9, 0, 0).unwrap(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = make_server();
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = vec![0, 1];
    let sizes = vec![1.0f32; 2];
    std::thread::scope(|s| {
        for _ in 0..2 {
            let ep = actual.clone();
            // Both workers ready to drop client 1's slot; 1 of 2 < 0.9
            // quorum.
            s.spawn(move || {
                worker(&ep, Roles { disconnect_on: Some(1), ..Roles::good() })
            });
        }
        let params = RoundParams {
            round: 0,
            round_seed: 7,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let err = srv.run_round(&mut agg, &params, &mut w).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("quorum target"), "{msg}");
        srv.shutdown();
    });
    assert_eq!(srv.connected(), 0, "failed round drops its connections");
    assert!(w.iter().all(|&x| x == 0.0), "no partial round may step the model");
}
