//! End-to-end training integration tests: every strategy must train the
//! smoke task (loss decreases), runs must be deterministic, and the
//! compression accounting must reflect each method's wire format.

use std::path::PathBuf;
use std::sync::Arc;

use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::Trainer;
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;

fn artifacts_ready() -> bool {
    let ok = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
    }
    ok
}

fn smoke_cfg(strategy: StrategyConfig, rounds: usize) -> TrainConfig {
    TrainConfig {
        task: "smoke".into(),
        strategy,
        rounds,
        clients_per_round: 4,
        lr: LrSchedule::Triangular { peak: 0.2, pivot: 0.25 },
        scale: DataScale::smoke(),
        eval_every: 0,
        seed: 5,
        artifacts_dir: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

fn all_strategies() -> Vec<(&'static str, StrategyConfig)> {
    vec![
        (
            "fetchsgd",
            StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
        ),
        (
            "local_topk",
            StrategyConfig::LocalTopK { k: 50, rho_g: 0.9, masking: true, local_error: false },
        ),
        ("fedavg", StrategyConfig::FedAvg { local_steps: 2, rho_g: 0.0 }),
        ("uncompressed", StrategyConfig::Uncompressed { rho_g: 0.9 }),
        ("true_topk", StrategyConfig::TrueTopK { k: 50, rho: 0.9, masking: true }),
    ]
}

#[test]
fn every_strategy_reduces_training_loss() {
    if !artifacts_ready() {
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    for (name, strat) in all_strategies() {
        let mut t = Trainer::with_runtime(smoke_cfg(strat, 25), runtime.clone()).unwrap();
        let s = t.run().unwrap();
        let first = t.logger.rounds[0].loss;
        assert!(
            s.final_loss < first * 0.7,
            "{name}: loss should drop ({first:.4} -> {:.4})",
            s.final_loss
        );
        assert!(s.accuracy > 0.3, "{name}: accuracy {:.3}", s.accuracy);
    }
}

#[test]
fn runs_are_deterministic() {
    if !artifacts_ready() {
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let run = || {
        let mut t = Trainer::with_runtime(
            smoke_cfg(
                StrategyConfig::FetchSgd {
                    k: 50,
                    cols: 512,
                    rho: 0.9,
                    error_update: "zero_out".into(),
                    error_window: "vanilla".into(),
                    masking: true,
                },
                8,
            ),
            runtime.clone(),
        )
        .unwrap();
        t.run().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits());
    assert_eq!(a.upload_bytes, b.upload_bytes);
}

#[test]
fn accounting_matches_wire_formats() {
    if !artifacts_ready() {
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let manifest =
        fetchsgd::runtime::artifact::Manifest::load(&smoke_cfg(all_strategies()[0].1.clone(), 1).artifacts_dir)
            .unwrap();
    let d = manifest.task("smoke").unwrap().dim as u64;
    let rounds = 6u64;
    let w = 4u64;

    // FetchSGD: upload = rows*cols*4 per client per round.
    let mut t = Trainer::with_runtime(
        smoke_cfg(
            StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            rounds as usize,
        ),
        runtime.clone(),
    )
    .unwrap();
    let s = t.run().unwrap();
    assert_eq!(s.upload_bytes, 5 * 512 * 4 * rounds * w);
    // download: k-sparse values only
    assert_eq!(s.download_bytes, 50 * 4 * rounds * w);

    // Uncompressed: dense both ways.
    let mut t = Trainer::with_runtime(
        smoke_cfg(StrategyConfig::Uncompressed { rho_g: 0.9 }, rounds as usize),
        runtime.clone(),
    )
    .unwrap();
    let s = t.run().unwrap();
    assert_eq!(s.upload_bytes, d * 4 * rounds * w);
    assert_eq!(s.download_bytes, d * 4 * rounds * w);
    let r = s.ratios;
    assert!((r.upload - 1.0).abs() < 1e-9 && (r.overall - 1.0).abs() < 1e-9);

    // Local top-k: upload k values; download <= W*k values.
    let mut t = Trainer::with_runtime(
        smoke_cfg(
            StrategyConfig::LocalTopK { k: 50, rho_g: 0.0, masking: false, local_error: false },
            rounds as usize,
        ),
        runtime,
    )
    .unwrap();
    let s = t.run().unwrap();
    assert_eq!(s.upload_bytes, 50 * 4 * rounds * w);
    assert!(s.download_bytes <= 50 * w * 4 * rounds * w);
}

#[test]
fn sliding_window_error_accumulator_trains() {
    if !artifacts_ready() {
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    for window in ["ring:4", "log:8"] {
        let mut t = Trainer::with_runtime(
            smoke_cfg(
                StrategyConfig::FetchSgd {
                    k: 50,
                    cols: 512,
                    rho: 0.9,
                    error_update: "zero_out".into(),
                    error_window: window.into(),
                    masking: true,
                },
                20,
            ),
            runtime.clone(),
        )
        .unwrap();
        let s = t.run().unwrap();
        assert!(s.accuracy > 0.3, "{window}: accuracy {:.3}", s.accuracy);
    }
}

#[test]
fn trainer_rejects_invalid_configs() {
    if !artifacts_ready() {
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    // cols not lowered for this task
    let err = Trainer::with_runtime(
        smoke_cfg(
            StrategyConfig::FetchSgd {
                k: 50,
                cols: 4096,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            2,
        ),
        runtime.clone(),
    )
    .err()
    .expect("should reject unknown cols");
    assert!(format!("{err:#}").contains("cols"));
    // fedavg steps not lowered
    assert!(Trainer::with_runtime(
        smoke_cfg(StrategyConfig::FedAvg { local_steps: 99, rho_g: 0.0 }, 2),
        runtime,
    )
    .is_err());
}
