//! End-to-end tracing over a depth-2 relay tree: a root `RoundServer`
//! in relay mode and two leaf `Relay` nodes, every tier writing its own
//! trace file, merged by the same `trace::summary` folder the
//! `trace-summary` CLI runs. The acceptance bar is twofold:
//!
//! 1. **Neutrality** — the traced tree produces bitwise-identical final
//!    weights and losses to the untraced tree. Tracing is observation,
//!    never input.
//! 2. **Reconstruction** — the three files merge into one coherent
//!    timeline: every round present under both tiers, the root's five
//!    server phases and the relays' subtree phases spanned, relay slot
//!    events stamped with *global* slot ids covering the cohort, the
//!    root attributing each absorbed slot to its delivering chain, and
//!    the relay-tier arrival histogram carrying exactly one sample per
//!    slot per round.

use std::sync::Arc;
use std::time::Duration;

use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{sim_artifacts, SimDataset, SimSketchClient};
use fetchsgd::coordinator::ClientSelector;
use fetchsgd::relay::{Relay, RelayOptions};
use fetchsgd::trace::summary::{fold_files, render};
use fetchsgd::trace::TraceSink;
use fetchsgd::transport::{join, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions};
use fetchsgd::util::rng::derive_seed;

const DIM: usize = 8_192;
const ROWS: usize = 3;
const COLS: usize = 256;
const SEED: u64 = 0xBEEF;
const ROUNDS: usize = 2;
const COHORT: usize = 8;
const NUM_CLIENTS: usize = 64;
const RELAYS: usize = 2;
const FANOUT: usize = 2;
const T60: Duration = Duration::from_secs(60);

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn make_client() -> SimSketchClient {
    SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }
}

fn make_server() -> FetchSgdServer {
    FetchSgdServer::new(ROWS, COLS, SEED, DIM, 16, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
        .unwrap()
}

fn cohort_for(round: usize) -> (Vec<usize>, Vec<f32>) {
    let selector = ClientSelector::new(NUM_CLIENTS, COHORT, SEED);
    let participants = selector.select(round);
    let sizes = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
    (participants, sizes)
}

/// Run the whole tree — root, `RELAYS` leaf relays, `FANOUT` honest
/// socket workers per relay — with tracing on every tier when
/// `trace_dir` is set. Returns (final weights, losses).
fn tree_train(trace_dir: Option<&std::path::Path>) -> (Vec<f32>, Vec<f32>) {
    let client = make_client();
    let mut server = make_server();
    let root_sink = trace_dir.map(|d| {
        Arc::new(TraceSink::create(&d.join("root.jsonl"), "root", "tcp:loopback").unwrap())
    });
    let opts = ServeOptions {
        workers: 0,
        relay_children: RELAYS,
        read_timeout: T60,
        accept_timeout: T60,
        trace: root_sink.clone(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let root = srv.local_endpoint().unwrap();
    let (w, losses) = std::thread::scope(|s| {
        for r in 0..RELAYS {
            let mut node = Relay::bind(
                &Endpoint::Tcp("127.0.0.1:0".into()),
                RelayOptions {
                    workers: FANOUT,
                    read_timeout: T60,
                    accept_timeout: T60,
                    trace_path: trace_dir.map(|d| d.join(format!("relay{r}.jsonl"))),
                    ..Default::default()
                },
            )
            .unwrap();
            let down = node.local_endpoint().unwrap();
            let up = root.clone();
            s.spawn(move || {
                let sum = node.run(&up).unwrap();
                assert_eq!(sum.rounds, ROUNDS);
            });
            for _ in 0..FANOUT {
                let ep = down.clone();
                let client = &client;
                s.spawn(move || {
                    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                    let dataset = SimDataset { num_clients: NUM_CLIENTS };
                    let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                    let sum = join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                    assert_eq!(sum.rounds, ROUNDS);
                });
            }
        }
        let mut w = vec![0f32; DIM];
        let mut losses = Vec::new();
        for round in 0..ROUNDS {
            let (parts, sizes) = cohort_for(round);
            let params = RoundParams {
                round: round as u64,
                round_seed: derive_seed(SEED, round as u64),
                lr: 0.05,
                participants: &parts,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(&mut server, &params, &mut w).unwrap();
            losses.extend_from_slice(&stats.losses);
        }
        srv.shutdown();
        (w, losses)
    });
    if let Some(sink) = &root_sink {
        sink.flush().unwrap();
    }
    (w, losses)
}

#[test]
fn depth2_tree_traces_merge_and_stay_bitwise_neutral() {
    let dir = std::env::temp_dir().join(format!("fsgd_tp_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let (w_plain, l_plain) = tree_train(None);
    assert!(w_plain.iter().any(|&x| x != 0.0), "training must move the model");
    let (w_traced, l_traced) = tree_train(Some(&dir));

    // 1. Neutrality: tracing on every tier never perturbs the bits.
    assert_eq!(bits(&w_plain), bits(&w_traced), "tracing perturbed the tree weights");
    assert_eq!(bits(&l_plain), bits(&l_traced), "tracing perturbed the tree losses");

    // 2. Reconstruction: merge the three per-tier files exactly as the
    //    `trace-summary` CLI does.
    let paths =
        [dir.join("root.jsonl"), dir.join("relay0.jsonl"), dir.join("relay1.jsonl")];
    for p in &paths {
        assert!(p.exists(), "missing trace file {}", p.display());
    }
    let report = fold_files(&paths).unwrap();
    assert_eq!(report.unknown_lines, 0, "tree emitted an event the folder does not know");
    assert_eq!(report.files, 3);
    let mut tiers: Vec<&str> = report.sources.iter().map(|(t, _)| t.as_str()).collect();
    tiers.sort_unstable();
    assert_eq!(tiers, ["relay", "relay", "root"]);
    assert_eq!(report.rounds.len(), ROUNDS);

    let root = "root".to_string();
    let relay = "relay".to_string();
    for (round, tl) in &report.rounds {
        // Root: the five server phases of a relay-mode round.
        for phase in ["plan", "absorb_wait", "finalize", "reduce", "broadcast"] {
            assert!(
                tl.phases.contains_key(&(root.clone(), phase.to_string())),
                "round {round} missing root-tier {phase} span"
            );
        }
        // Relays: the subtree phases, merged across both leaf files.
        for phase in ["plan", "absorb_wait", "finalize", "reduce"] {
            let agg = tl
                .phases
                .get(&(relay.clone(), phase.to_string()))
                .unwrap_or_else(|| panic!("round {round} missing relay-tier {phase} span"));
            assert_eq!(agg.count, RELAYS as u64, "one {phase} span per relay per round");
        }
        // Relay slot events carry *global* slot ids: across both
        // relays the offered/absorbed sets tile the whole cohort.
        assert_eq!(tl.events[&(relay.clone(), "offered".to_string())], COHORT as u64);
        assert_eq!(tl.events[&(relay.clone(), "absorbed".to_string())], COHORT as u64);
        // The root attributes every absorbed slot to a delivering
        // chain — COHORT slots per round, peer-tagged.
        assert_eq!(tl.events[&(root.clone(), "absorbed".to_string())], COHORT as u64);
    }

    // Exactly one arrival sample per slot per round, merged bucketwise
    // across the two relay files.
    let h = &report.hists[&(relay.clone(), "slot_arrival_us".to_string())];
    assert_eq!(h.count(), (ROUNDS * COHORT) as u64);

    // Per-connection IO: each relay heard from FANOUT workers, the
    // root from RELAYS chains; merged by (tier, peer).
    for peer in 0..FANOUT as u64 {
        assert!(report.conn_totals.contains_key(&(relay.clone(), peer)));
    }
    for peer in 0..RELAYS as u64 {
        assert!(report.conn_totals.contains_key(&(root.clone(), peer)));
    }

    // The human rendering carries its headline sections.
    let text = render(&report);
    assert!(text.contains("trace summary: 3 file(s)"));
    assert!(text.contains("per-phase totals (all rounds):"));
    assert!(text.contains("per-round timeline:"));
    std::fs::remove_dir_all(&dir).ok();
}
