//! Parallel determinism: the round engine's thread count must be a pure
//! throughput knob, and wire mode under the lossless `f32le` codec must
//! be a pure accounting knob. Same config + seed ⇒ bitwise-identical
//! final weights and losses whether the round runs on the PR-1-style
//! sequential reduce path, the streaming engine at parallelism 1, 3, or
//! 8, or the wire-framed variant of any of those.
//!
//! The multi-round loops here run on simulated clients (no PJRT, no
//! artifacts) for fetchsgd, a sparse top-k, and a dense baseline; a
//! Trainer-level check over the real smoke artifacts runs when
//! `artifacts/` is present.

use std::path::PathBuf;
use std::sync::Arc;

use fetchsgd::cohort::{DropReason, QuorumPolicy, RoundMembership};
use fetchsgd::compression::aggregate::{
    reduce_shards_in_place, shard_count, shard_of, PipelineOptions, RoundAccum, RoundPipeline,
};
use fetchsgd::compression::ClientUpload;
use fetchsgd::sketch::CountSketch;
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::local_topk::LocalTopKServer;
use fetchsgd::compression::sim::{
    sim_artifacts, SimDataset, SimDenseClient, SimSketchClient, SimTopKClient,
};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientCompute, ServerAggregator};
use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::{engine, ClientSelector, Trainer};
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;
use fetchsgd::util::rng::derive_seed;
use fetchsgd::wire::{Codec, F32LE};

const DIM: usize = 30_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xD5;
const ROUNDS: usize = 5;
const COHORT: usize = 24; // > MAX_SHARDS, so shards hold multiple slots

/// A miniature training loop over the sim stack — the streaming engine
/// pipeline exactly as the Trainer drives it, including pool reuse and
/// the optional wire round-trip of uploads and broadcasts. Returns
/// (final weights, all per-round losses, total measured wire upload
/// bytes).
fn sim_train(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    threads: usize,
    wire: Option<&'static dyn Codec>,
) -> (Vec<f32>, Vec<f32>, u64) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(dataset.num_clients, COHORT, SEED);
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut wire_upload_bytes = 0u64;
    let policy = QuorumPolicy::strict();
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.05,
            round_seed: derive_seed(SEED, round as u64),
            threads,
            wire,
            policy: &policy,
            round: round as u64,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        losses.extend_from_slice(&out.losses);
        wire_upload_bytes += out.wire_upload_bytes_per_client * participants.len() as u64;
        if wire.is_some() {
            assert!(
                out.wire_upload_bytes_per_client > out.upload_bytes_per_client,
                "measured frame bytes must exceed the idealized estimate"
            );
        }
        let update = server.finish(&out.merged, 0.05).unwrap();
        pipeline.recycle(out.merged);
        let update = match wire {
            Some(codec) => {
                let frame = fetchsgd::wire::encode_update(&update, codec);
                assert!(frame.len() as u64 >= update.payload_bytes());
                fetchsgd::wire::decode_update(&frame).unwrap()
            }
            None => update,
        };
        update.apply(&mut w);
    }
    (w, losses, wire_upload_bytes)
}

/// The PR-1 reference reduce path, by hand: compute every slot
/// *sequentially in slot order*, absorb each upload into the fixed
/// shard layout, join, then reduce shards sequentially. No pipeline, no
/// parking, no threads, no wire — the ground truth the streaming engine
/// must reproduce bit for bit.
fn reference_train(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
) -> (Vec<f32>, Vec<f32>) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(dataset.num_clients, COHORT, SEED);
    let stacked_k = client.wants_stacked_batches();
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let lambdas = server.begin_round(&sizes);
        let round_seed = derive_seed(SEED, round as u64);
        let spec = server.upload_spec();
        let nshards = shard_count(participants.len());
        let mut shards: Vec<RoundAccum> =
            (0..nshards).map(|_| RoundAccum::new(&spec).unwrap()).collect();
        for (slot, &c) in participants.iter().enumerate() {
            let batch = dataset.client_batch(c, round_seed);
            let stacked = stacked_k.map(|k| dataset.client_batches_stacked(c, k, round_seed));
            let res = client
                .client_round(&artifacts, &w, &batch, c, stacked, 0.05)
                .unwrap();
            losses.push(res.loss);
            shards[shard_of(slot, nshards)].absorb(res.upload, lambdas[slot]).unwrap();
        }
        reduce_shards_in_place(&mut shards, 1).unwrap();
        assert_eq!(shards[0].absorbed(), participants.len());
        let update = server.finish(&shards[0], 0.05).unwrap();
        update.apply(&mut w);
    }
    (w, losses)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

type ServerFactory = Box<dyn Fn() -> Box<dyn ServerAggregator>>;

fn strategy_cases() -> Vec<(&'static str, Box<dyn ClientCompute>, ServerFactory)> {
    vec![
        (
            "fetchsgd",
            Box::new(SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }),
            Box::new(|| {
                Box::new(
                    FetchSgdServer::new(
                        ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
                    )
                    .unwrap(),
                ) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "local_topk",
            Box::new(SimTopKClient { dim: DIM, heavy: 4, k: 40 }),
            Box::new(|| {
                Box::new(LocalTopKServer::new(DIM, 0.9, false)) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "uncompressed",
            Box::new(SimDenseClient { dim: DIM, heavy: 4 }),
            Box::new(|| Box::new(UncompressedServer::new(DIM, 0.9)) as Box<dyn ServerAggregator>),
        ),
    ]
}

/// Acceptance: the streaming engine is bitwise identical to the PR-1
/// sequential reduce path across the whole strategy × wire-on/off ×
/// parallelism-{1,3,8} matrix. Wire mode under the lossless `f32le`
/// codec additionally measures nonzero frame bytes; the reference path
/// (and wire-off runs) measure none.
#[test]
fn streaming_engine_matches_reference_reduce_across_matrix() {
    for (name, client, make_server) in &strategy_cases() {
        let (w_ref, l_ref) = {
            let mut server = make_server();
            reference_train(client.as_ref(), server.as_mut())
        };
        assert!(w_ref.iter().any(|&x| x != 0.0), "{name}: training must move the model");
        for wire in [None, Some(&F32LE as &'static dyn Codec)] {
            for threads in [1usize, 3, 8] {
                let mut server = make_server();
                let (w, l, measured) =
                    sim_train(client.as_ref(), server.as_mut(), threads, wire);
                let tag = if wire.is_some() { "wire=f32le" } else { "wire=off" };
                if wire.is_some() {
                    assert!(measured > 0, "{name}: wire mode must measure frame bytes");
                } else {
                    assert_eq!(measured, 0, "{name}: no wire bytes measured when wire is off");
                }
                assert_eq!(
                    bits(&w_ref),
                    bits(&w),
                    "{name}: weights diverge from the reference reduce \
                     (threads {threads}, {tag})"
                );
                assert_eq!(
                    bits(&l_ref),
                    bits(&l),
                    "{name}: losses diverge from the reference reduce \
                     (threads {threads}, {tag})"
                );
            }
        }
    }
}

/// Finalize-at-quorum keeps the determinism contract: for a fixed
/// final membership set, the renormalized merge is bitwise identical
/// at any reduce parallelism and any arrival order — renormalization
/// is a pure function of (weights, set), never of scheduling.
#[test]
fn finalize_partial_is_bitwise_stable_across_reduce_parallelism() {
    let slots = 20usize;
    let spec = fetchsgd::compression::UploadSpec::Sketch {
        rows: ROWS,
        cols: COLS,
        dim: DIM,
        seed: SEED,
    };
    let mut rng = fetchsgd::util::Rng::new(77);
    let uploads: Vec<ClientUpload> = (0..slots)
        .map(|_| {
            let g: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian() as f32).collect();
            ClientUpload::Sketch(CountSketch::encode(ROWS, COLS, SEED, &g).unwrap())
        })
        .collect();
    let weights: Vec<f32> = (0..slots).map(|i| 1.0 / (2.0 + i as f32)).collect();
    let dropped = [0usize, 7, 16]; // 0 and 16 share a shard
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let run = |reduce_parallelism: usize, reverse: bool| {
        let mut pl =
            RoundPipeline::new(PipelineOptions { reduce_parallelism, ..Default::default() });
        let mut m = RoundMembership::new(slots, policy.clone()).unwrap();
        let mut r = pl.begin(&spec, weights.clone()).unwrap();
        let mut order: Vec<usize> = (0..slots).filter(|s| !dropped.contains(s)).collect();
        if reverse {
            order.reverse();
        }
        for &slot in &order {
            r.offer(slot, uploads[slot].clone()).unwrap();
            m.record_arrival(slot);
        }
        for &slot in &dropped {
            m.record_drop(slot, DropReason::Deadline);
        }
        pl.finalize_partial(r, &m).unwrap().into_sketch().unwrap().table().to_vec()
    };
    let base = run(1, false);
    assert!(base.iter().any(|&x| x != 0.0));
    for (par, reverse) in [(1usize, true), (3, false), (8, true)] {
        let other = run(par, reverse);
        assert_eq!(
            bits(&base),
            bits(&other),
            "partial finalize diverged at reduce_parallelism {par} (reverse {reverse})"
        );
    }
}

#[test]
fn trainer_runs_are_bitwise_identical_across_parallelism() {
    // Full-stack variant over the real smoke artifacts; skips politely
    // on a fresh checkout like the other integration tests.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let run = |parallelism: usize, wire: Option<&str>| {
        let cfg = TrainConfig {
            task: "smoke".into(),
            strategy: StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            rounds: 6,
            clients_per_round: 4,
            lr: LrSchedule::Triangular { peak: 0.2, pivot: 0.25 },
            scale: DataScale::smoke(),
            eval_every: 0,
            seed: 5,
            artifacts_dir: dir.clone(),
            log_path: None,
            baseline_rounds: None,
            verbose: false,
            parallelism,
            wire: wire.map(String::from),
            // Pin a nontrivial reduce width: the row-strip reduction
            // must not perturb the full-stack trajectory either.
            reduce_parallelism: 4,
            ..TrainConfig::default_smoke()
        };
        let mut t = Trainer::with_runtime(cfg, runtime.clone()).unwrap();
        let s = t.run().unwrap();
        (t.weights().to_vec(), s)
    };
    let (w1, s1) = run(1, None);
    let (w8, s8) = run(8, None);
    assert_eq!(bits(&w1), bits(&w8), "trainer weights diverge at parallelism 8");
    assert_eq!(s1.final_loss.to_bits(), s8.final_loss.to_bits());
    assert_eq!(s1.eval_loss.to_bits(), s8.eval_loss.to_bits());
    assert_eq!(s1.accuracy.to_bits(), s8.accuracy.to_bits());
    assert_eq!(s1.upload_bytes, s8.upload_bytes);
    assert_eq!(s1.download_bytes, s8.download_bytes);
    assert_eq!(s1.wire_upload_bytes, 0);
    // Wire mode through the full Trainer: bitwise-identical weights,
    // measured bytes >= idealized bytes.
    let (w_wire, s_wire) = run(8, Some("f32le"));
    assert_eq!(bits(&w1), bits(&w_wire), "trainer weights diverge in wire mode");
    assert_eq!(s1.final_loss.to_bits(), s_wire.final_loss.to_bits());
    assert!(s_wire.wire_upload_bytes >= s_wire.upload_bytes);
    assert!(s_wire.wire_download_bytes >= s_wire.download_bytes);
}

/// Tracing is observation, never input: the same engine loop with a
/// `TraceSink` attached produces bitwise-identical weights and losses,
/// while the trace file itself reconstructs the engine-tier timeline
/// (phase spans, full slot lifecycle, per-round arrival histogram).
#[test]
fn tracing_is_bitwise_neutral_in_engine() {
    use fetchsgd::trace::summary::{fold_text, TraceReport};
    use fetchsgd::trace::TraceSink;

    let cases = strategy_cases();
    let (_, client, make_server) = &cases[0]; // fetchsgd
    let (w_ref, l_ref, _) = {
        let mut server = make_server();
        sim_train(client.as_ref(), server.as_mut(), 3, None)
    };

    let dir = std::env::temp_dir().join(format!("fsgd_pd_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("engine.jsonl");
    let sink = Arc::new(TraceSink::create(&path, "engine", "sim").unwrap());

    // The sim_train loop, verbatim, with the sink attached.
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(dataset.num_clients, COHORT, SEED);
    let mut server = make_server();
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let policy = QuorumPolicy::strict();
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: client.as_ref(),
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.05,
            round_seed: derive_seed(SEED, round as u64),
            threads: 3,
            wire: None,
            policy: &policy,
            round: round as u64,
            trace: Some(sink.clone()),
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        losses.extend_from_slice(&out.losses);
        let update = server.finish(&out.merged, 0.05).unwrap();
        pipeline.recycle(out.merged);
        update.apply(&mut w);
    }
    sink.flush().unwrap();

    assert_eq!(bits(&w_ref), bits(&w), "tracing perturbed the engine weights");
    assert_eq!(bits(&l_ref), bits(&losses), "tracing perturbed the engine losses");

    // The emitted trace reconstructs the run: every round present, the
    // four engine phases spanned, every slot offered and folded, and an
    // exact arrival histogram.
    let mut report = TraceReport::default();
    fold_text(&mut report, &std::fs::read_to_string(&path).unwrap(), "engine.jsonl").unwrap();
    assert_eq!(report.unknown_lines, 0);
    assert_eq!(report.rounds.len(), ROUNDS);
    let engine_tier = "engine".to_string();
    for (round, tl) in &report.rounds {
        for phase in ["plan", "compute", "finalize", "reduce"] {
            assert!(
                tl.phases.contains_key(&(engine_tier.clone(), phase.to_string())),
                "round {round} missing engine-tier {phase} span"
            );
        }
        assert_eq!(tl.events[&(engine_tier.clone(), "offered".to_string())], COHORT as u64);
        // Every slot lands exactly once: absorbed in order, or parked
        // and later folded out of the parking buffer.
        let absorbed = tl.events.get(&(engine_tier.clone(), "absorbed".to_string())).copied();
        let folded = tl.events.get(&(engine_tier.clone(), "folded".to_string())).copied();
        assert_eq!(
            absorbed.unwrap_or(0) + folded.unwrap_or(0),
            COHORT as u64,
            "round {round}: absorbed + folded must cover the cohort"
        );
        let parked = tl.events.get(&(engine_tier.clone(), "parked".to_string())).copied();
        assert_eq!(parked.unwrap_or(0), folded.unwrap_or(0), "every parked slot must fold");
    }
    let h = &report.hists[&(engine_tier.clone(), "slot_arrival_us".to_string())];
    assert_eq!(h.count(), (ROUNDS * COHORT) as u64, "one arrival sample per slot per round");
    std::fs::remove_dir_all(&dir).ok();
}
