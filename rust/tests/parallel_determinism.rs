//! Parallel determinism: the round engine's thread count must be a pure
//! throughput knob, and wire mode under the lossless `f32le` codec must
//! be a pure accounting knob. Same config + seed ⇒ bitwise-identical
//! final weights, losses, and run summaries at `parallelism = 1` and
//! `parallelism = 8`, wire on or off.
//!
//! The multi-round loops here run on simulated clients (no PJRT, no
//! artifacts) for fetchsgd, a sparse top-k, and a dense baseline; a
//! Trainer-level check over the real smoke artifacts runs when
//! `artifacts/` is present.

use std::path::PathBuf;
use std::sync::Arc;

use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::local_topk::LocalTopKServer;
use fetchsgd::compression::sim::{
    sim_artifacts, SimDataset, SimDenseClient, SimSketchClient, SimTopKClient,
};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientCompute, ServerAggregator};
use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::{engine, ClientSelector, Trainer};
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;
use fetchsgd::util::rng::derive_seed;
use fetchsgd::wire::{Codec, F32LE};

const DIM: usize = 30_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xD5;
const ROUNDS: usize = 5;
const COHORT: usize = 24; // > MAX_SHARDS, so shards hold multiple slots

/// A miniature training loop over the sim stack — the engine pipeline
/// exactly as the Trainer drives it, including scratch-accumulator
/// reuse and the optional wire round-trip of uploads and broadcasts.
/// Returns (final weights, all per-round losses, total measured wire
/// upload bytes).
fn sim_train(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    threads: usize,
    wire: Option<&'static dyn Codec>,
) -> (Vec<f32>, Vec<f32>, u64) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(dataset.num_clients, COHORT, SEED);
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut scratch = Vec::new();
    let mut wire_upload_bytes = 0u64;
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.05,
            round_seed: derive_seed(SEED, round as u64),
            threads,
            wire,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut scratch)
                .unwrap();
        losses.extend_from_slice(&out.losses);
        wire_upload_bytes += out.wire_upload_bytes_per_client * participants.len() as u64;
        if wire.is_some() {
            assert!(
                out.wire_upload_bytes_per_client > out.upload_bytes_per_client,
                "measured frame bytes must exceed the idealized estimate"
            );
        }
        let update = server.finish(&out.merged, 0.05).unwrap();
        scratch.push(out.merged);
        let update = match wire {
            Some(codec) => {
                let frame = fetchsgd::wire::encode_update(&update, codec);
                assert!(frame.len() as u64 >= update.payload_bytes());
                fetchsgd::wire::decode_update(&frame).unwrap()
            }
            None => update,
        };
        update.apply(&mut w);
    }
    (w, losses, wire_upload_bytes)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn fetchsgd_is_bitwise_identical_across_parallelism() {
    let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 };
    let run = |threads: usize| {
        let mut server = FetchSgdServer::new(
            ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        sim_train(&client, &mut server, threads, None)
    };
    let (w1, l1, _) = run(1);
    let (w8, l8, _) = run(8);
    assert!(w1.iter().any(|&x| x != 0.0), "training must move the model");
    assert_eq!(bits(&w1), bits(&w8), "fetchsgd weights diverge at parallelism 8");
    assert_eq!(bits(&l1), bits(&l8), "fetchsgd losses diverge at parallelism 8");
}

#[test]
fn dense_baseline_is_bitwise_identical_across_parallelism() {
    let client = SimDenseClient { dim: DIM, heavy: 4 };
    let run = |threads: usize| {
        let mut server = UncompressedServer::new(DIM, 0.9);
        sim_train(&client, &mut server, threads, None)
    };
    let (w1, l1, _) = run(1);
    let (w8, l8, _) = run(8);
    assert!(w1.iter().any(|&x| x != 0.0), "training must move the model");
    assert_eq!(bits(&w1), bits(&w8), "dense weights diverge at parallelism 8");
    assert_eq!(bits(&l1), bits(&l8), "dense losses diverge at parallelism 8");
}

/// Acceptance: wire mode under the lossless `f32le` codec is a pure
/// accounting knob — weights bitwise identical to wire-off at
/// parallelism 1 and 8, for the sketch, sparse, and dense upload paths.
#[test]
fn wire_mode_f32le_is_bitwise_identical_to_in_memory() {
    type ServerFactory = Box<dyn Fn() -> Box<dyn ServerAggregator>>;
    let cases: Vec<(&str, Box<dyn ClientCompute>, ServerFactory)> = vec![
        (
            "fetchsgd",
            Box::new(SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }),
            Box::new(|| {
                Box::new(
                    FetchSgdServer::new(
                        ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
                    )
                    .unwrap(),
                ) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "local_topk",
            Box::new(SimTopKClient { dim: DIM, heavy: 4, k: 40 }),
            Box::new(|| {
                Box::new(LocalTopKServer::new(DIM, 0.9, false)) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "uncompressed",
            Box::new(SimDenseClient { dim: DIM, heavy: 4 }),
            Box::new(|| Box::new(UncompressedServer::new(DIM, 0.9)) as Box<dyn ServerAggregator>),
        ),
    ];
    for (name, client, make_server) in &cases {
        let run = |threads: usize, wire: Option<&'static dyn Codec>| {
            let mut server = make_server();
            sim_train(client.as_ref(), server.as_mut(), threads, wire)
        };
        let (w_mem, l_mem, wire0) = run(1, None);
        assert_eq!(wire0, 0, "{name}: no wire bytes measured when wire is off");
        assert!(w_mem.iter().any(|&x| x != 0.0), "{name}: training must move the model");
        for threads in [1usize, 8] {
            let (w_wire, l_wire, measured) = run(threads, Some(&F32LE));
            assert!(measured > 0, "{name}: wire mode must measure frame bytes");
            assert_eq!(
                bits(&w_mem),
                bits(&w_wire),
                "{name}: wire round-trip changed the weights (threads {threads})"
            );
            assert_eq!(
                bits(&l_mem),
                bits(&l_wire),
                "{name}: wire round-trip changed the losses (threads {threads})"
            );
        }
    }
}

#[test]
fn trainer_runs_are_bitwise_identical_across_parallelism() {
    // Full-stack variant over the real smoke artifacts; skips politely
    // on a fresh checkout like the other integration tests.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let run = |parallelism: usize, wire: Option<&str>| {
        let cfg = TrainConfig {
            task: "smoke".into(),
            strategy: StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            rounds: 6,
            clients_per_round: 4,
            lr: LrSchedule::Triangular { peak: 0.2, pivot: 0.25 },
            scale: DataScale::smoke(),
            eval_every: 0,
            seed: 5,
            artifacts_dir: dir.clone(),
            log_path: None,
            baseline_rounds: None,
            verbose: false,
            parallelism,
            wire: wire.map(String::from),
            transport: None,
            transport_workers: 1,
        };
        let mut t = Trainer::with_runtime(cfg, runtime.clone()).unwrap();
        let s = t.run().unwrap();
        (t.weights().to_vec(), s)
    };
    let (w1, s1) = run(1, None);
    let (w8, s8) = run(8, None);
    assert_eq!(bits(&w1), bits(&w8), "trainer weights diverge at parallelism 8");
    assert_eq!(s1.final_loss.to_bits(), s8.final_loss.to_bits());
    assert_eq!(s1.eval_loss.to_bits(), s8.eval_loss.to_bits());
    assert_eq!(s1.accuracy.to_bits(), s8.accuracy.to_bits());
    assert_eq!(s1.upload_bytes, s8.upload_bytes);
    assert_eq!(s1.download_bytes, s8.download_bytes);
    assert_eq!(s1.wire_upload_bytes, 0);
    // Wire mode through the full Trainer: bitwise-identical weights,
    // measured bytes >= idealized bytes.
    let (w_wire, s_wire) = run(8, Some("f32le"));
    assert_eq!(bits(&w1), bits(&w_wire), "trainer weights diverge in wire mode");
    assert_eq!(s1.final_loss.to_bits(), s_wire.final_loss.to_bits());
    assert!(s_wire.wire_upload_bytes >= s_wire.upload_bytes);
    assert!(s_wire.wire_download_bytes >= s_wire.download_bytes);
}
