//! Parallel determinism: the round engine's thread count must be a pure
//! throughput knob. Same config + seed ⇒ bitwise-identical final
//! weights, losses, and run summaries at `parallelism = 1` and
//! `parallelism = 8`.
//!
//! The multi-round loops here run on simulated clients (no PJRT, no
//! artifacts) for fetchsgd and a dense baseline; a Trainer-level check
//! over the real smoke artifacts runs when `artifacts/` is present.

use std::path::PathBuf;
use std::sync::Arc;

use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{sim_artifacts, SimDataset, SimDenseClient, SimSketchClient};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientCompute, ServerAggregator};
use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::{engine, ClientSelector, Trainer};
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;
use fetchsgd::util::rng::derive_seed;

const DIM: usize = 30_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xD5;
const ROUNDS: usize = 5;
const COHORT: usize = 24; // > MAX_SHARDS, so shards hold multiple slots

/// A miniature training loop over the sim stack; returns
/// (final weights, all per-round losses).
fn sim_train(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    threads: usize,
) -> (Vec<f32>, Vec<f32>) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: 200 };
    let selector = ClientSelector::new(dataset.num_clients, COHORT, SEED);
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let out = engine::run_round(
            client,
            &artifacts,
            &dataset,
            &participants,
            &weights,
            &server.upload_spec(),
            &w,
            0.05,
            derive_seed(SEED, round as u64),
            threads,
        )
        .unwrap();
        losses.extend(out.losses);
        server.finish(out.merged, &mut w, 0.05).unwrap();
    }
    (w, losses)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn fetchsgd_is_bitwise_identical_across_parallelism() {
    let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 };
    let run = |threads: usize| {
        let mut server = FetchSgdServer::new(
            ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        sim_train(&client, &mut server, threads)
    };
    let (w1, l1) = run(1);
    let (w8, l8) = run(8);
    assert!(w1.iter().any(|&x| x != 0.0), "training must move the model");
    assert_eq!(bits(&w1), bits(&w8), "fetchsgd weights diverge at parallelism 8");
    assert_eq!(bits(&l1), bits(&l8), "fetchsgd losses diverge at parallelism 8");
}

#[test]
fn dense_baseline_is_bitwise_identical_across_parallelism() {
    let client = SimDenseClient { dim: DIM, heavy: 4 };
    let run = |threads: usize| {
        let mut server = UncompressedServer::new(DIM, 0.9);
        sim_train(&client, &mut server, threads)
    };
    let (w1, l1) = run(1);
    let (w8, l8) = run(8);
    assert!(w1.iter().any(|&x| x != 0.0), "training must move the model");
    assert_eq!(bits(&w1), bits(&w8), "dense weights diverge at parallelism 8");
    assert_eq!(bits(&l1), bits(&l8), "dense losses diverge at parallelism 8");
}

#[test]
fn trainer_runs_are_bitwise_identical_across_parallelism() {
    // Full-stack variant over the real smoke artifacts; skips politely
    // on a fresh checkout like the other integration tests.
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let runtime = Arc::new(Runtime::cpu().unwrap());
    let run = |parallelism: usize| {
        let cfg = TrainConfig {
            task: "smoke".into(),
            strategy: StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            rounds: 6,
            clients_per_round: 4,
            lr: LrSchedule::Triangular { peak: 0.2, pivot: 0.25 },
            scale: DataScale::smoke(),
            eval_every: 0,
            seed: 5,
            artifacts_dir: dir.clone(),
            log_path: None,
            baseline_rounds: None,
            verbose: false,
            parallelism,
        };
        let mut t = Trainer::with_runtime(cfg, runtime.clone()).unwrap();
        let s = t.run().unwrap();
        (t.weights().to_vec(), s)
    };
    let (w1, s1) = run(1);
    let (w8, s8) = run(8);
    assert_eq!(bits(&w1), bits(&w8), "trainer weights diverge at parallelism 8");
    assert_eq!(s1.final_loss.to_bits(), s8.final_loss.to_bits());
    assert_eq!(s1.eval_loss.to_bits(), s8.eval_loss.to_bits());
    assert_eq!(s1.accuracy.to_bits(), s8.accuracy.to_bits());
    assert_eq!(s1.upload_bytes, s8.upload_bytes);
    assert_eq!(s1.download_bytes, s8.download_bytes);
}
