//! Tree determinism: hierarchical aggregation must be a pure
//! deployment knob. A two-level tree — a root `RoundServer` in relay
//! mode over mid-tier `relay::Relay` nodes, each serving its own
//! socket workers — produces bitwise-identical final weights and
//! losses to a flat server pinned to the same shard layout
//! (`ServeOptions::shards = R`) and to the in-process engine
//! (`PipelineOptions::shard_override = R`), for the sketch, sparse,
//! and dense upload paths, over TCP and UDS — the acceptance bar for
//! the relay subsystem.
//!
//! Why this holds: relay `r` owns the slots shard `r` would own in a
//! flat round (`s % R == r`), folds them in ascending order with the
//! *global* λ shipped in its assignment, and the root absorbs each
//! merged frame into exactly that shard with weight 1 — weighted sums
//! reassociate exactly because the accumulators are linear (pinned at
//! the unit level in `aggregate::chain_frames_reassociate_to_flat_bits`).
//! Renormalization happens once, at the root, so a partial round
//! closed at quorum with a dropped downstream worker also matches the
//! flat server ending with the same surviving membership set.
//!
//! Since protocol v4 the same two shapes nest: a depth-3 tree (root →
//! interior relays → leaf relays → workers) must match a flat server
//! pinned to the tree's *tiered* layout (`shards = R·K`,
//! `shard_tiers = [R, K]`) and the in-process engine with the same
//! `reduce_tiers` — leaf `(r, k)` owns exactly the global slots
//! `≡ r + k·R (mod R·K)`, i.e. flat shard `r + k·R`, and the tiered
//! reduce rebuilds each subtree's fold. The depth-3 tests below also
//! pin the failure-tolerance half of the contract: an interior relay
//! reporting a *partial* chain at quorum, and a dead leaf relay whose
//! chain is re-assigned mid-round to its surviving sibling.

use std::time::Duration;

use fetchsgd::cohort::QuorumPolicy;
use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::local_topk::LocalTopKServer;
use fetchsgd::compression::sim::{
    sim_artifacts, SimDataset, SimDenseClient, SimSketchClient, SimTopKClient,
};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientCompute, ServerAggregator};
use fetchsgd::coordinator::{engine, ClientSelector};
use fetchsgd::data::FedDataset;
use fetchsgd::relay::{Relay, RelayOptions};
use fetchsgd::transport::framing::{read_msg, write_msg};
use fetchsgd::transport::proto::{Msg, PROTO_VERSION};
use fetchsgd::transport::{
    join, Conn, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions,
};
use fetchsgd::util::rng::derive_seed;

const DIM: usize = 30_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xD5;
const ROUNDS: usize = 4;
const COHORT: usize = 24;
const NUM_CLIENTS: usize = 200;
const RELAYS: usize = 2;
const T60: Duration = Duration::from_secs(60);

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[cfg(unix)]
fn uds_endpoint(tag: &str) -> Endpoint {
    let path = std::env::temp_dir().join(format!("fsgw_relay_{}_{tag}.sock", std::process::id()));
    Endpoint::Unix(path)
}

fn cohort_for(round: usize) -> (Vec<usize>, Vec<f32>) {
    let selector = ClientSelector::new(NUM_CLIENTS, COHORT, SEED);
    let participants = selector.select(round);
    let sizes = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
    (participants, sizes)
}

/// The in-process reference loop, with the pipeline pinned to the
/// tree's shard layout (`shard_override = R`, and for a depth > 2 tree
/// the tiered reduce `reduce_tiers = [R, K, …]`). Mirrors
/// `transport_determinism.rs::sim_train`.
fn sim_train_sharded(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    shard_override: usize,
    tiers: &[usize],
    rounds: usize,
) -> (Vec<f32>, Vec<f32>) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: NUM_CLIENTS };
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut pipeline = RoundPipeline::new(PipelineOptions {
        shard_override,
        reduce_tiers: tiers.to_vec(),
        ..Default::default()
    });
    let policy = QuorumPolicy::strict();
    for round in 0..rounds {
        let (participants, sizes) = cohort_for(round);
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.05,
            round_seed: derive_seed(SEED, round as u64),
            threads: 2,
            wire: None,
            policy: &policy,
            round: round as u64,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        losses.extend_from_slice(&out.losses);
        let update = server.finish(&out.merged, 0.05).unwrap();
        pipeline.recycle(out.merged);
        update.apply(&mut w);
    }
    (w, losses)
}

struct RootRun {
    w: Vec<f32>,
    losses: Vec<f32>,
    transport_bytes: u64,
    participants: usize,
}

/// Drive `ROUNDS` server rounds with the shared cohort schedule, then
/// shut the tier down.
fn drive_root(srv: &mut RoundServer, server: &mut dyn ServerAggregator) -> RootRun {
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut transport_bytes = 0u64;
    let mut participants = 0usize;
    for round in 0..ROUNDS {
        let (parts, sizes) = cohort_for(round);
        let params = RoundParams {
            round: round as u64,
            round_seed: derive_seed(SEED, round as u64),
            lr: 0.05,
            participants: &parts,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(server, &params, &mut w).unwrap();
        losses.extend_from_slice(&stats.losses);
        transport_bytes += stats.transport_bytes;
        participants += stats.participants;
    }
    srv.shutdown();
    RootRun { w, losses, transport_bytes, participants }
}

/// Flat comparator: a single server over `workers` socket workers with
/// the shard layout pinned to the tree's relay count (and, for a
/// depth > 2 tree, the tiered reduce pinned to its fan-out per tier).
fn flat_train(
    ep: &Endpoint,
    workers: usize,
    shards: usize,
    tiers: &[usize],
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
) -> RootRun {
    let opts = ServeOptions {
        workers,
        shards,
        shard_tiers: tiers.to_vec(),
        read_timeout: T60,
        accept_timeout: T60,
        ..Default::default()
    };
    let mut srv = RoundServer::bind(ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let ep = actual.clone();
            s.spawn(move || {
                let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                let dataset = SimDataset { num_clients: NUM_CLIENTS };
                let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                join(&ep, client, &dataset, &artifacts, &opts).unwrap();
            });
        }
        drive_root(&mut srv, server)
    })
}

/// Two-level tree: root in relay mode, `RELAYS` relays each serving
/// `fanout` honest socket workers via `transport::join`.
fn tree_train(
    root_ep: &Endpoint,
    relay_eps: Vec<Endpoint>,
    fanout: usize,
    quorum: QuorumPolicy,
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
) -> RootRun {
    let relays = relay_eps.len();
    let opts = ServeOptions {
        workers: 0,
        relay_children: relays,
        read_timeout: T60,
        accept_timeout: T60,
        quorum,
        ..Default::default()
    };
    let mut srv = RoundServer::bind(root_ep, opts).unwrap();
    let root = srv.local_endpoint().unwrap();
    std::thread::scope(|s| {
        for rep in &relay_eps {
            let mut node = Relay::bind(
                rep,
                RelayOptions {
                    workers: fanout,
                    read_timeout: T60,
                    accept_timeout: T60,
                    ..Default::default()
                },
            )
            .unwrap();
            let down = node.local_endpoint().unwrap();
            let up = root.clone();
            s.spawn(move || {
                let sum = node.run(&up).unwrap();
                assert_eq!(sum.rounds, ROUNDS);
            });
            for _ in 0..fanout {
                let ep = down.clone();
                s.spawn(move || {
                    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                    let dataset = SimDataset { num_clients: NUM_CLIENTS };
                    let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                    let sum = join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                    assert_eq!(sum.rounds, ROUNDS);
                });
            }
        }
        drive_root(&mut srv, server)
    })
}

fn sketch_strategy() -> (Box<dyn ClientCompute>, Box<dyn ServerAggregator>) {
    (
        Box::new(SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }),
        Box::new(
            FetchSgdServer::new(ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
                .unwrap(),
        ),
    )
}

type ServerFactory = Box<dyn Fn() -> Box<dyn ServerAggregator>>;

fn strategies() -> Vec<(&'static str, Box<dyn ClientCompute>, ServerFactory)> {
    vec![
        (
            "fetchsgd",
            Box::new(SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }),
            Box::new(|| sketch_strategy().1),
        ),
        (
            "local_topk",
            Box::new(SimTopKClient { dim: DIM, heavy: 4, k: 40 }),
            Box::new(|| {
                Box::new(LocalTopKServer::new(DIM, 0.9, false)) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "uncompressed",
            Box::new(SimDenseClient { dim: DIM, heavy: 4 }),
            Box::new(|| Box::new(UncompressedServer::new(DIM, 0.9)) as Box<dyn ServerAggregator>),
        ),
    ]
}

/// Acceptance: over UDS, a two-level tree (2 relays × 2 workers) is
/// bitwise identical to the flat server and the in-process engine on
/// the same shard layout, for sketch, sparse, and dense upload paths.
#[cfg(unix)]
#[test]
fn uds_two_level_tree_is_bitwise_identical_to_flat_and_in_process() {
    for (name, client, make_server) in &strategies() {
        let (w_mem, l_mem) =
            sim_train_sharded(client.as_ref(), make_server().as_mut(), RELAYS, &[], ROUNDS);
        assert!(w_mem.iter().any(|&x| x != 0.0), "{name}: training must move the model");
        let flat = flat_train(
            &uds_endpoint(&format!("flat_{name}")),
            3,
            RELAYS,
            &[],
            client.as_ref(),
            make_server().as_mut(),
        );
        assert_eq!(bits(&w_mem), bits(&flat.w), "{name}: flat weights diverge from in-process");
        assert_eq!(bits(&l_mem), bits(&flat.losses), "{name}: flat losses diverge");
        let relay_eps =
            (0..RELAYS).map(|r| uds_endpoint(&format!("r{r}_{name}"))).collect();
        let tree = tree_train(
            &uds_endpoint(&format!("root_{name}")),
            relay_eps,
            2,
            QuorumPolicy::strict(),
            client.as_ref(),
            make_server().as_mut(),
        );
        assert_eq!(bits(&w_mem), bits(&tree.w), "{name}: tree weights diverge from in-process");
        assert_eq!(bits(&l_mem), bits(&tree.losses), "{name}: tree losses diverge");
        assert_eq!(tree.participants, ROUNDS * COHORT, "{name}: tree dropped slots");
    }
}

/// The same tree over loopback TCP, and the headline scaling property:
/// the root link carries one merged frame per relay per round, so the
/// root's on-the-wire byte count is *independent of downstream
/// fan-out* (1 worker per relay vs 4), while the weights stay bitwise
/// identical.
#[test]
fn tcp_tree_matches_flat_and_root_bytes_are_fanout_independent() {
    let tcp = || Endpoint::Tcp("127.0.0.1:0".into());
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let flat = flat_train(&tcp(), 3, RELAYS, &[], client.as_ref(), make_server().as_mut());
    let narrow = tree_train(
        &tcp(),
        (0..RELAYS).map(|_| tcp()).collect(),
        1,
        QuorumPolicy::strict(),
        client.as_ref(),
        make_server().as_mut(),
    );
    let wide = tree_train(
        &tcp(),
        (0..RELAYS).map(|_| tcp()).collect(),
        4,
        QuorumPolicy::strict(),
        client.as_ref(),
        make_server().as_mut(),
    );
    assert_eq!(bits(&flat.w), bits(&narrow.w), "tcp tree weights diverge from flat");
    assert_eq!(bits(&flat.losses), bits(&narrow.losses), "tcp tree losses diverge from flat");
    assert_eq!(bits(&narrow.w), bits(&wide.w), "fan-out must not change the bits");
    assert_eq!(
        narrow.transport_bytes, wide.transport_bytes,
        "root-link bytes must be independent of downstream fan-out"
    );
}

/// A scripted protocol-level worker: serves honest client compute, but
/// when `fail` is `(round, slot)` it silently disconnects on reading
/// the `RoundStart` of `round` *iff* its assignment includes `slot` —
/// which pins the dropped membership set without depending on
/// accept-order races.
fn scripted_worker(mut conn: Conn, client: &dyn ClientCompute, fail: Option<(u64, u32)>) {
    use fetchsgd::wire::{codec_by_id, decode_dense_frame, encode_upload};
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: NUM_CLIENTS };
    conn.set_timeouts(Some(T60), Some(T60)).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    loop {
        let (bytes, _) = read_msg(&mut conn, 64 << 20).unwrap();
        match Msg::decode(bytes).unwrap() {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                if let Some((fail_round, fail_slot)) = fail {
                    if round == fail_round && assignments.iter().any(|&(s, _)| s == fail_slot) {
                        conn.shutdown();
                        return;
                    }
                }
                let codec = codec_by_id(codec_id).unwrap();
                let w = decode_dense_frame(&weights_frame).unwrap();
                for (slot, cid) in assignments {
                    let c = cid as usize;
                    let batch = dataset.client_batch(c, round_seed);
                    let stacked = client
                        .wants_stacked_batches()
                        .map(|k| dataset.client_batches_stacked(c, k, round_seed));
                    let res = client.client_round(&artifacts, &w, &batch, c, stacked, lr).unwrap();
                    let frame = encode_upload(&res.upload, codec);
                    write_msg(&mut conn, &Msg::Upload { slot, loss: res.loss, frame }.encode())
                        .unwrap();
                }
            }
            Msg::RoundEnd { .. } => {}
            Msg::Shutdown | Msg::Abort { .. } => return,
            other => panic!("unexpected {} message", other.kind_name()),
        }
    }
}

/// Acceptance: a partial round closed at quorum with a dropped
/// downstream worker is bitwise identical between the tree and the
/// flat server over the same surviving membership set.
///
/// Construction: in the final round, the worker holding global slot 2
/// disconnects after `RoundStart`. In the tree (2 relays × 2 workers,
/// workers dialed in order) that worker owns the odd local slots of
/// the chain `{0, 2, 4, …}`, i.e. globals `{2, 6, 10, …, 22}`; in the
/// flat run (4 workers, `shards = 2`) the worker at connection index 2
/// owns slots `≡ 2 (mod 4)` — the same set. 18 of 24 slots survive,
/// clearing the 0.5 quorum, and renormalization over the survivors
/// happens at the root in both layouts.
#[test]
fn partial_round_at_quorum_matches_between_tree_and_flat() {
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let fail = Some(((ROUNDS - 1) as u64, 2u32));
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let tcp = || Endpoint::Tcp("127.0.0.1:0".into());

    // Flat: 4 scripted workers, dialed sequentially so connection
    // index is deterministic (only the *failing* worker's identity
    // depends on it, and that is re-derived from its assignment).
    let flat = {
        let opts = ServeOptions {
            workers: 4,
            shards: RELAYS,
            read_timeout: T60,
            accept_timeout: T60,
            quorum: policy.clone(),
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let actual = srv.local_endpoint().unwrap();
        let conns: Vec<Conn> = (0..4).map(|_| Conn::connect(&actual).unwrap()).collect();
        std::thread::scope(|s| {
            for conn in conns {
                let client = client.as_ref();
                s.spawn(move || scripted_worker(conn, client, fail));
            }
            drive_root(&mut srv, make_server().as_mut())
        })
    };

    // Tree: both relays' second-dialed worker carries the fail script;
    // only the one whose assignment includes global slot 2 trips it.
    let tree = {
        let opts = ServeOptions {
            workers: 0,
            relay_children: RELAYS,
            read_timeout: T60,
            accept_timeout: T60,
            quorum: policy.clone(),
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let root = srv.local_endpoint().unwrap();
        std::thread::scope(|s| {
            for _ in 0..RELAYS {
                let mut node = Relay::bind(
                    &tcp(),
                    RelayOptions {
                        workers: 2,
                        read_timeout: T60,
                        accept_timeout: T60,
                        ..Default::default()
                    },
                )
                .unwrap();
                let down = node.local_endpoint().unwrap();
                let up = root.clone();
                s.spawn(move || {
                    node.run(&up).unwrap();
                });
                // Dial order pins local striping: first connection gets
                // the even local slots, second the odd ones.
                for w in 0..2 {
                    let conn = Conn::connect(&down).unwrap();
                    let client = client.as_ref();
                    let script = if w == 1 { fail } else { None };
                    s.spawn(move || scripted_worker(conn, client, script));
                }
            }
            drive_root(&mut srv, make_server().as_mut())
        })
    };

    let dropped = COHORT / 4;
    assert_eq!(flat.participants, ROUNDS * COHORT - dropped, "flat run dropped the wrong slots");
    assert_eq!(tree.participants, flat.participants, "tree and flat membership differ");
    assert_eq!(bits(&flat.w), bits(&tree.w), "partial-round weights diverge");
    assert_eq!(bits(&flat.losses), bits(&tree.losses), "partial-round losses diverge");
}

/// Membership roll-up edge case end-to-end: with fewer global slots
/// than relays, the tail relay receives an empty chain every round,
/// must answer immediately (no downstream pool needed), and the tree
/// still matches a flat server pinned to the same (clamped) layout.
#[test]
fn zero_participant_subtree_rounds_complete_and_match_flat() {
    const SMALL: usize = 2; // slots per round, < 3 relays
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let tcp = || Endpoint::Tcp("127.0.0.1:0".into());
    let pick = |round: usize| -> (Vec<usize>, Vec<f32>) {
        let participants: Vec<usize> =
            (0..SMALL).map(|i| (round * 31 + 7 * i + 1) % NUM_CLIENTS).collect();
        let sizes = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        (participants, sizes)
    };
    let drive = |srv: &mut RoundServer, server: &mut dyn ServerAggregator| -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0f32; DIM];
        let mut losses = Vec::new();
        for round in 0..ROUNDS {
            let (parts, sizes) = pick(round);
            let params = RoundParams {
                round: round as u64,
                round_seed: derive_seed(SEED, round as u64),
                lr: 0.05,
                participants: &parts,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(server, &params, &mut w).unwrap();
            assert_eq!(stats.participants, SMALL, "round {round} dropped a slot");
            losses.extend_from_slice(&stats.losses);
        }
        srv.shutdown();
        (w, losses)
    };

    let (w_flat, l_flat) = {
        let opts = ServeOptions {
            workers: 2,
            shards: 3,
            read_timeout: T60,
            accept_timeout: T60,
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let actual = srv.local_endpoint().unwrap();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let ep = actual.clone();
                let client = client.as_ref();
                s.spawn(move || {
                    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                    let dataset = SimDataset { num_clients: NUM_CLIENTS };
                    let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                    join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                });
            }
            drive(&mut srv, make_server().as_mut())
        })
    };

    let (w_tree, l_tree) = {
        let opts = ServeOptions {
            workers: 0,
            relay_children: 3,
            read_timeout: T60,
            accept_timeout: T60,
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let root = srv.local_endpoint().unwrap();
        std::thread::scope(|s| {
            for _ in 0..3 {
                let mut node = Relay::bind(
                    &tcp(),
                    RelayOptions {
                        workers: 1,
                        read_timeout: T60,
                        accept_timeout: T60,
                        ..Default::default()
                    },
                )
                .unwrap();
                let down = node.local_endpoint().unwrap();
                let up = root.clone();
                s.spawn(move || {
                    node.run(&up).unwrap();
                });
                let ep = down.clone();
                let client = client.as_ref();
                s.spawn(move || {
                    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                    let dataset = SimDataset { num_clients: NUM_CLIENTS };
                    let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                    // The relay that only ever receives empty chains
                    // answers the root without touching its downstream
                    // pool, so this worker is never accepted and errors
                    // out when the relay's listener closes — both a
                    // clean run and that error are fine here. A worker
                    // failure under a *serving* relay still fails the
                    // test through the root's round result.
                    let _ = join(&ep, client, &dataset, &artifacts, &opts);
                });
            }
            drive(&mut srv, make_server().as_mut())
        })
    };

    assert_eq!(bits(&w_flat), bits(&w_tree), "zero-participant-subtree weights diverge");
    assert_eq!(bits(&l_flat), bits(&l_tree), "zero-participant-subtree losses diverge");
}

// ---------------------------------------------------------------------------
// Depth-3 trees (protocol v4): root → interior relays → leaf relays.
// ---------------------------------------------------------------------------

#[path = "common/faults.rs"]
mod faults;

/// Interior relays under the root, and leaf relays under each interior
/// relay, in the depth-3 tests. The matching flat layout is
/// `shards = INTERIOR * LEAVES_PER`, `shard_tiers = [INTERIOR,
/// LEAVES_PER]`.
const INTERIOR: usize = 2;
const LEAVES_PER: usize = 2;

/// Depth-3 tree: root in relay mode over `INTERIOR` interior relays
/// (`relay_children = LEAVES_PER`), each over `LEAVES_PER` leaf
/// relays, each serving `leaf_workers` honest socket workers via
/// `transport::join`.
fn depth3_tree_train(
    mk_ep: &dyn Fn(String) -> Endpoint,
    leaf_workers: usize,
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
) -> RootRun {
    let opts = ServeOptions {
        workers: 0,
        relay_children: INTERIOR,
        read_timeout: T60,
        accept_timeout: T60,
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&mk_ep("root".into()), opts).unwrap();
    let root = srv.local_endpoint().unwrap();
    std::thread::scope(|s| {
        for i in 0..INTERIOR {
            let mut mid = Relay::bind(
                &mk_ep(format!("mid{i}")),
                RelayOptions {
                    workers: 0,
                    relay_children: LEAVES_PER,
                    read_timeout: T60,
                    accept_timeout: T60,
                    ..Default::default()
                },
            )
            .unwrap();
            let mid_down = mid.local_endpoint().unwrap();
            let up = root.clone();
            s.spawn(move || {
                let sum = mid.run(&up).unwrap();
                assert_eq!(sum.rounds, ROUNDS);
            });
            for l in 0..LEAVES_PER {
                let mut leaf = Relay::bind(
                    &mk_ep(format!("leaf{i}{l}")),
                    RelayOptions {
                        workers: leaf_workers,
                        read_timeout: T60,
                        accept_timeout: T60,
                        ..Default::default()
                    },
                )
                .unwrap();
                let down = leaf.local_endpoint().unwrap();
                let up = mid_down.clone();
                s.spawn(move || {
                    let sum = leaf.run(&up).unwrap();
                    assert_eq!(sum.rounds, ROUNDS);
                });
                for _ in 0..leaf_workers {
                    let ep = down.clone();
                    s.spawn(move || {
                        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                        let dataset = SimDataset { num_clients: NUM_CLIENTS };
                        let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                        let sum = join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                        assert_eq!(sum.rounds, ROUNDS);
                    });
                }
            }
        }
        drive_root(&mut srv, server)
    })
}

/// Acceptance (depth 3): over UDS, a three-level tree (2 interior × 2
/// leaf relays × 1 worker each) is bitwise identical to the flat
/// server pinned to the tiered layout (`shards = 4`,
/// `shard_tiers = [2, 2]`) and to the in-process engine with the same
/// `reduce_tiers`, for sketch, sparse, and dense upload paths.
#[cfg(unix)]
#[test]
fn uds_depth3_tree_is_bitwise_identical_to_flat_and_in_process() {
    let nshards = INTERIOR * LEAVES_PER;
    let tiers = [INTERIOR, LEAVES_PER];
    for (name, client, make_server) in &strategies() {
        let (w_mem, l_mem) =
            sim_train_sharded(client.as_ref(), make_server().as_mut(), nshards, &tiers, ROUNDS);
        assert!(w_mem.iter().any(|&x| x != 0.0), "{name}: training must move the model");
        let flat = flat_train(
            &uds_endpoint(&format!("d3flat_{name}")),
            3,
            nshards,
            &tiers,
            client.as_ref(),
            make_server().as_mut(),
        );
        assert_eq!(bits(&w_mem), bits(&flat.w), "{name}: tiered flat weights diverge");
        assert_eq!(bits(&l_mem), bits(&flat.losses), "{name}: tiered flat losses diverge");
        let tree = depth3_tree_train(
            &|tag| uds_endpoint(&format!("d3{tag}{name}")),
            1,
            client.as_ref(),
            make_server().as_mut(),
        );
        assert_eq!(bits(&w_mem), bits(&tree.w), "{name}: depth-3 weights diverge");
        assert_eq!(bits(&l_mem), bits(&tree.losses), "{name}: depth-3 losses diverge");
        assert_eq!(tree.participants, ROUNDS * COHORT, "{name}: depth-3 tree dropped slots");
    }
}

/// The same depth-3 tree over loopback TCP: transport must not matter
/// at any depth, so the tree matches the in-process tiered engine.
#[test]
fn tcp_depth3_tree_matches_in_process() {
    let nshards = INTERIOR * LEAVES_PER;
    let tiers = [INTERIOR, LEAVES_PER];
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let (w_mem, l_mem) =
        sim_train_sharded(client.as_ref(), make_server().as_mut(), nshards, &tiers, ROUNDS);
    let tree = depth3_tree_train(
        &|_| Endpoint::Tcp("127.0.0.1:0".into()),
        1,
        client.as_ref(),
        make_server().as_mut(),
    );
    assert_eq!(bits(&w_mem), bits(&tree.w), "tcp depth-3 weights diverge from in-process");
    assert_eq!(bits(&l_mem), bits(&tree.losses), "tcp depth-3 losses diverge from in-process");
}

/// Acceptance (depth 3, partial chain): in the final round one leaf
/// worker dies after `RoundStart`, so its leaf relay reports a
/// *partial* chain — per-slot outcomes plus a merged frame weighted
/// only by the arrived slots — which the interior relay rolls up
/// unchanged. The root closes at quorum, and the bits equal a flat
/// tiered server losing the same worker: same surviving set ⇒ same
/// bits.
///
/// Striping: with 8 leaf workers (2 per leaf) the worker holding
/// global slot 2 owns exactly the slots `≡ 2 (mod 8)`; in the flat run
/// (8 workers) the connection holding slot 2 owns the same set — the
/// scripted failure triggers on the assignment, never on accept order.
#[test]
fn depth3_partial_chain_at_quorum_matches_flat() {
    let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
    let fail = Some(((ROUNDS - 1) as u64, 2u32));
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let tcp = || Endpoint::Tcp("127.0.0.1:0".into());
    let nshards = INTERIOR * LEAVES_PER;
    let tiers = [INTERIOR, LEAVES_PER];

    // Flat tiered comparator: 8 scripted workers, one carrying the
    // same death as the tree's doomed leaf worker.
    let flat = {
        let opts = ServeOptions {
            workers: 8,
            shards: nshards,
            shard_tiers: tiers.to_vec(),
            read_timeout: T60,
            accept_timeout: T60,
            quorum: policy.clone(),
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let actual = srv.local_endpoint().unwrap();
        let conns: Vec<Conn> = (0..8).map(|_| Conn::connect(&actual).unwrap()).collect();
        std::thread::scope(|s| {
            for conn in conns {
                let client = client.as_ref();
                s.spawn(move || scripted_worker(conn, client, fail));
            }
            drive_root(&mut srv, make_server().as_mut())
        })
    };

    // Depth-3 tree: every leaf worker carries the script; only the one
    // whose final-round assignment includes global slot 2 trips it.
    let tree = {
        let opts = ServeOptions {
            workers: 0,
            relay_children: INTERIOR,
            read_timeout: T60,
            accept_timeout: T60,
            quorum: policy.clone(),
            ..Default::default()
        };
        let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
        let root = srv.local_endpoint().unwrap();
        std::thread::scope(|s| {
            for _ in 0..INTERIOR {
                let mut mid = Relay::bind(
                    &tcp(),
                    RelayOptions {
                        workers: 0,
                        relay_children: LEAVES_PER,
                        read_timeout: T60,
                        accept_timeout: T60,
                        ..Default::default()
                    },
                )
                .unwrap();
                let mid_down = mid.local_endpoint().unwrap();
                let up = root.clone();
                s.spawn(move || {
                    mid.run(&up).unwrap();
                });
                for _ in 0..LEAVES_PER {
                    let mut leaf = Relay::bind(
                        &tcp(),
                        RelayOptions {
                            workers: 2,
                            read_timeout: T60,
                            accept_timeout: T60,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let down = leaf.local_endpoint().unwrap();
                    let up = mid_down.clone();
                    s.spawn(move || {
                        leaf.run(&up).unwrap();
                    });
                    for _ in 0..2 {
                        let conn = Conn::connect(&down).unwrap();
                        let client = client.as_ref();
                        s.spawn(move || scripted_worker(conn, client, fail));
                    }
                }
            }
            drive_root(&mut srv, make_server().as_mut())
        })
    };

    let dropped = COHORT / 8;
    assert_eq!(flat.participants, ROUNDS * COHORT - dropped, "flat run dropped the wrong slots");
    assert_eq!(tree.participants, flat.participants, "tree and flat membership differ");
    assert_eq!(bits(&flat.w), bits(&tree.w), "depth-3 partial weights diverge");
    assert_eq!(bits(&flat.losses), bits(&tree.losses), "depth-3 partial losses diverge");
}

/// Acceptance (depth 3, re-assignment): a leaf relay accepts its
/// subtree and dies mid-merge. Its interior relay re-offers the whole
/// unserved chain to the surviving sibling leaf — same round, a second
/// `SubtreeAssign` — which serves it through its own workers. Under a
/// *full* quorum the round may only close if the rescue really
/// happened, and because the rescued chain lands in the dead child's
/// accumulator, the bits equal the full-membership in-process
/// reference exactly.
#[test]
fn depth3_dead_leaf_relay_chain_is_reassigned_mid_round() {
    use faults::{dial, evil_vanish_mid_merge};

    // Full quorum + one retry: the round can only succeed via rescue.
    let policy = QuorumPolicy::new(1.0, 0, 1).unwrap();
    let (client, _) = sketch_strategy();
    let make_server = || sketch_strategy().1;
    let tcp = || Endpoint::Tcp("127.0.0.1:0".into());
    let nshards = INTERIOR * LEAVES_PER;
    let tiers = [INTERIOR, LEAVES_PER];

    // Full-membership in-process reference, one round.
    let (w_ref, l_ref) =
        sim_train_sharded(client.as_ref(), make_server().as_mut(), nshards, &tiers, 1);

    let opts = ServeOptions {
        workers: 0,
        relay_children: INTERIOR,
        read_timeout: T60,
        accept_timeout: T60,
        quorum: policy.clone(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&tcp(), opts).unwrap();
    let root = srv.local_endpoint().unwrap();
    let (w_tree, stats) = std::thread::scope(|s| {
        for i in 0..INTERIOR {
            let mut mid = Relay::bind(
                &tcp(),
                RelayOptions {
                    workers: 0,
                    relay_children: LEAVES_PER,
                    read_timeout: T60,
                    accept_timeout: T60,
                    quorum: policy.clone(),
                    ..Default::default()
                },
            )
            .unwrap();
            let mid_down = mid.local_endpoint().unwrap();
            let up = root.clone();
            s.spawn(move || {
                mid.run(&up).unwrap();
            });
            // Interior 0 gets one honest leaf and the doomed peer;
            // interior 1 gets two honest leaves.
            let honest_leaves = if i == 0 { 1 } else { LEAVES_PER };
            for _ in 0..honest_leaves {
                let mut leaf = Relay::bind(
                    &tcp(),
                    RelayOptions {
                        workers: 1,
                        read_timeout: T60,
                        accept_timeout: T60,
                        ..Default::default()
                    },
                )
                .unwrap();
                let down = leaf.local_endpoint().unwrap();
                let up = mid_down.clone();
                s.spawn(move || {
                    leaf.run(&up).unwrap();
                });
                let ep = down.clone();
                let client = client.as_ref();
                s.spawn(move || {
                    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                    let dataset = SimDataset { num_clients: NUM_CLIENTS };
                    let opts = JoinOptions { read_timeout: Some(T60), ..Default::default() };
                    // The surviving leaf serves a second subtree in the
                    // same round, so its worker sees more round starts
                    // than rounds; no round-count assertion here.
                    let _ = join(&ep, client, &dataset, &artifacts, &opts);
                });
            }
            if i == 0 {
                // The doomed leaf: a scripted relay peer that accepts
                // its chain and vanishes mid-merge.
                let ep = mid_down.clone();
                s.spawn(move || {
                    let mut conn = dial(&ep);
                    evil_vanish_mid_merge(&mut conn);
                });
            }
        }
        let (parts, sizes) = cohort_for(0);
        let params = RoundParams {
            round: 0,
            round_seed: derive_seed(SEED, 0),
            lr: 0.05,
            participants: &parts,
            client_sizes: &sizes,
        };
        let mut server = make_server();
        let mut w = vec![0f32; DIM];
        let stats = srv.run_round(server.as_mut(), &params, &mut w).unwrap();
        srv.shutdown();
        (w, stats)
    });

    assert_eq!(stats.participants, COHORT, "the rescued chain must make the round full");
    assert_eq!(stats.dropped_slots, 0, "no slot may drop when the rescue lands");
    assert!(stats.retried_slots > 0, "the re-assigned chain must be accounted as retried");
    assert_eq!(bits(&w_ref), bits(&w_tree), "rescued-round weights diverge from full reference");
    assert_eq!(bits(&l_ref), bits(&stats.losses), "rescued-round losses diverge");
}
