//! Streaming absorb under a straggler: one deliberately slow client
//! must not block absorption of already-arrived uploads.
//!
//! The proof is direct observation, not timing: the server's
//! absorbed-count probe must reach W−1 while the straggler's upload is
//! still *withheld* (it waits on a channel the test releases only after
//! seeing the count), which is impossible if the server buffered the
//! cohort behind a barrier. The round then completes and the result is
//! bitwise identical to the in-process reference, so streaming changed
//! latency, never bits.
//!
//! Under a quorum policy with a round deadline, the same gated
//! straggler is *dropped* instead of waited for: the round completes
//! with the arrived subset and renormalized weights (second test). A
//! slow-loris peer — trickling bytes so the per-read socket timeout
//! never fires — is evicted by the same wall-clock deadline (third
//! test).
//!
//! The scripted peers live in the shared harness (`common/faults.rs`).

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use fetchsgd::cohort::{DropReason, QuorumPolicy, RoundMembership};
use fetchsgd::compression::aggregate::{run_server_round, PipelineOptions, RoundPipeline};
use fetchsgd::compression::sim::synth_grad;
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientUpload, ServerAggregator, UploadSpec};
use fetchsgd::transport::{Endpoint, RoundParams, RoundServer, ServeOptions};

#[path = "common/faults.rs"]
mod faults;
use faults::{dial, evil_slow_loris, gated_worker, start_round, tolerant_straggler, DIM, HEAVY};

const W: usize = 4;
const LR: f32 = 0.05;
const SEED: u64 = 0xABCD;

#[test]
fn straggler_does_not_block_streaming_absorb() {
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let probe = srv.absorbed_probe();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        // Three prompt workers and one gated straggler.
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || gated_worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || gated_worker(&ep, Some(rx)));

        // The round runs on its own thread so this one can watch the
        // probe while the straggler is still withholding its upload.
        let server_round = s.spawn(|| {
            let params = RoundParams {
                round: 0,
                round_seed: SEED,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
            srv.shutdown();
            stats
        });

        // Streaming absorb, observed: all prompt uploads must fold in
        // while the straggler is provably still waiting on our gate.
        let deadline = Instant::now() + Duration::from_secs(20);
        while probe.load(Ordering::SeqCst) < W - 1 {
            assert!(Instant::now() < deadline, "prompt uploads were not absorbed while waiting");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            probe.load(Ordering::SeqCst),
            W - 1,
            "the withheld upload cannot have been absorbed"
        );
        // Release the straggler; the round must now complete.
        tx.send(()).unwrap();
        let stats = server_round.join().expect("server round panicked");
        assert_eq!(stats.losses.len(), W);
        assert_eq!(probe.load(Ordering::SeqCst), W);
    });

    // Streaming changed latency, never bits.
    let uploads: Vec<ClientUpload> = participants
        .iter()
        .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, SEED)))
        .collect();
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    run_server_round(&mut agg_ref, &sizes, uploads, &mut w_ref, LR).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w));
}

/// Finalize-at-quorum reference: a full in-process round over every
/// slot except `dropped_slot`, dropped with `reason`, under `policy`.
fn quorum_reference(
    participants: &[usize],
    sizes: &[f32],
    dropped_slot: usize,
    reason: DropReason,
    policy: QuorumPolicy,
) -> Vec<f32> {
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    let lambdas = agg_ref.begin_round(sizes);
    let spec: UploadSpec = agg_ref.upload_spec();
    let mut pl = RoundPipeline::new(PipelineOptions::default());
    let mut m = RoundMembership::new(participants.len(), policy).unwrap();
    let mut r = pl.begin(&spec, lambdas).unwrap();
    for slot in 0..participants.len() {
        if slot == dropped_slot {
            continue;
        }
        let g = synth_grad(DIM, HEAVY, participants[slot], SEED);
        r.offer(slot, ClientUpload::Dense(g)).unwrap();
        m.record_arrival(slot);
    }
    m.record_drop(dropped_slot, reason);
    let merged = pl.finalize_partial(r, &m).unwrap();
    let update = agg_ref.finish(&merged, LR).unwrap();
    let mut w_ref = vec![0f32; DIM];
    update.apply(&mut w_ref);
    w_ref
}

/// Quorum counterpart of the probe test: with `round_deadline_ms` set
/// and `quorum_fraction = 0.5`, the round *completes* once the deadline
/// fires — the gated straggler is dropped, not waited for, and the
/// merged weights equal a finalize-at-quorum reference over the same
/// surviving membership set, bit for bit.
#[test]
fn straggler_past_deadline_is_dropped_at_quorum() {
    let policy = QuorumPolicy::new(0.5, 2000, 0).unwrap();
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        quorum: policy.clone(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];
    let (tx, rx) = mpsc::channel();

    let stats = std::thread::scope(|s| {
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || gated_worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || tolerant_straggler(&ep, rx));
        let params = RoundParams {
            round: 0,
            round_seed: SEED,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        srv.shutdown();
        // Only now may the straggler move — the round closed without
        // it.
        tx.send(()).ok();
        stats
    });

    assert_eq!(stats.participants, W - 1, "round completes with the arrived subset");
    assert_eq!(stats.dropped_slots, 1, "the straggler's slot is dropped");
    assert_eq!(stats.retried_slots, 0);
    assert!(w.iter().any(|&x| x != 0.0), "the partial round still steps the model");

    // The straggler's slot is the one that reported no loss.
    let dropped_slot = stats.losses.iter().position(|&l| l == 0.0).expect("one dropped slot");

    let w_ref = quorum_reference(&participants, &sizes, dropped_slot, DropReason::Deadline, policy);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w), "deadline drop changed the surviving slots' math");
}

/// Slow-loris counterpart: the hostile peer trickles its upload one
/// byte at a time, so the per-read socket timeout never fires — each
/// byte arrives "in time" — and only the wall-clock round deadline can
/// evict it. The round must close at quorum with the slow-loris slot
/// dropped for `Deadline`, and the surviving slots' math untouched.
#[test]
fn slow_loris_upload_is_dropped_at_the_round_deadline() {
    let policy = QuorumPolicy::new(0.5, 2000, 0).unwrap();
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        quorum: policy.clone(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];

    let stats = std::thread::scope(|s| {
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || gated_worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || {
            let mut conn = dial(&ep);
            let (seed, assignments) = start_round(&mut conn);
            let slot = assignments.first().map(|&(s, _)| s).unwrap_or(0);
            evil_slow_loris(&mut conn, slot, seed);
        });
        let params = RoundParams {
            round: 0,
            round_seed: SEED,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        srv.shutdown();
        stats
    });

    assert_eq!(stats.participants, W - 1, "round closes with the prompt workers");
    assert_eq!(stats.dropped_slots, 1, "the slow-loris slot is dropped");
    assert!(w.iter().any(|&x| x != 0.0), "the partial round still steps the model");

    let dropped_slot = stats.losses.iter().position(|&l| l == 0.0).expect("one dropped slot");

    let w_ref = quorum_reference(&participants, &sizes, dropped_slot, DropReason::Deadline, policy);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w), "slow-loris eviction changed the surviving slots' math");
}
