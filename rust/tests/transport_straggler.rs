//! Streaming absorb under a straggler: one deliberately slow client
//! must not block absorption of already-arrived uploads.
//!
//! The proof is direct observation, not timing: the server's
//! absorbed-count probe must reach W−1 while the straggler's upload is
//! still *withheld* (it waits on a channel the test releases only after
//! seeing the count), which is impossible if the server buffered the
//! cohort behind a barrier. The round then completes and the result is
//! bitwise identical to the in-process reference, so streaming changed
//! latency, never bits.

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use fetchsgd::compression::aggregate::run_server_round;
use fetchsgd::compression::sim::synth_grad;
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::ClientUpload;
use fetchsgd::transport::framing::{read_msg, write_msg};
use fetchsgd::transport::proto::{Msg, PROTO_VERSION};
use fetchsgd::transport::{Conn, Endpoint, RoundParams, RoundServer, ServeOptions};
use fetchsgd::wire::{encode_upload, F32LE};

const DIM: usize = 64;
const HEAVY: usize = 2;
const W: usize = 4;
const LR: f32 = 0.05;
const SEED: u64 = 0xABCD;

/// Hand-rolled worker: handshake, take the one assigned slot, wait for
/// `gate` (None = no wait), upload, drain round-end + shutdown.
fn worker(ep: &Endpoint, gate: Option<mpsc::Receiver<()>>) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(&mut conn, 64 << 20).unwrap();
    let (seed, assignments) = match Msg::decode(bytes).unwrap() {
        Msg::RoundStart { round_seed, assignments, .. } => (round_seed, assignments),
        _ => panic!("expected round-start"),
    };
    if let Some(rx) = gate {
        rx.recv_timeout(Duration::from_secs(30)).expect("straggler gate never released");
    }
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        write_msg(&mut conn, &Msg::Upload { slot, loss: 0.5, frame }.encode()).unwrap();
    }
    loop {
        let (bytes, _) = read_msg(&mut conn, 64 << 20).unwrap();
        match Msg::decode(bytes).unwrap() {
            Msg::RoundEnd { .. } => {}
            Msg::Shutdown => break,
            other => panic!("unexpected {}", other.kind_name()),
        }
    }
}

#[test]
fn straggler_does_not_block_streaming_absorb() {
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let probe = srv.absorbed_probe();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        // Three prompt workers and one gated straggler.
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || worker(&ep, Some(rx)));

        // The round runs on its own thread so this one can watch the
        // probe while the straggler is still withholding its upload.
        let server_round = s.spawn(|| {
            let params = RoundParams {
                round: 0,
                round_seed: SEED,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
            srv.shutdown();
            stats
        });

        // Streaming absorb, observed: all prompt uploads must fold in
        // while the straggler is provably still waiting on our gate.
        let deadline = Instant::now() + Duration::from_secs(20);
        while probe.load(Ordering::SeqCst) < W - 1 {
            assert!(Instant::now() < deadline, "prompt uploads were not absorbed while waiting");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            probe.load(Ordering::SeqCst),
            W - 1,
            "the withheld upload cannot have been absorbed"
        );
        // Release the straggler; the round must now complete.
        tx.send(()).unwrap();
        let stats = server_round.join().expect("server round panicked");
        assert_eq!(stats.losses.len(), W);
        assert_eq!(probe.load(Ordering::SeqCst), W);
    });

    // Streaming changed latency, never bits.
    let uploads: Vec<ClientUpload> = participants
        .iter()
        .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, SEED)))
        .collect();
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    run_server_round(&mut agg_ref, &sizes, uploads, &mut w_ref, LR).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w));
}
