//! Streaming absorb under a straggler: one deliberately slow client
//! must not block absorption of already-arrived uploads.
//!
//! The proof is direct observation, not timing: the server's
//! absorbed-count probe must reach W−1 while the straggler's upload is
//! still *withheld* (it waits on a channel the test releases only after
//! seeing the count), which is impossible if the server buffered the
//! cohort behind a barrier. The round then completes and the result is
//! bitwise identical to the in-process reference, so streaming changed
//! latency, never bits.
//!
//! Under a quorum policy with a round deadline, the same gated
//! straggler is *dropped* instead of waited for: the round completes
//! with the arrived subset and renormalized weights (second test).

use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use fetchsgd::cohort::{DropReason, QuorumPolicy, RoundMembership};
use fetchsgd::compression::aggregate::{run_server_round, PipelineOptions, RoundPipeline};
use fetchsgd::compression::sim::synth_grad;
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientUpload, ServerAggregator, UploadSpec};
use fetchsgd::transport::framing::{read_msg, write_msg};
use fetchsgd::transport::proto::{Msg, PROTO_VERSION};
use fetchsgd::transport::{Conn, Endpoint, RoundParams, RoundServer, ServeOptions};
use fetchsgd::wire::{encode_upload, F32LE};

const DIM: usize = 64;
const HEAVY: usize = 2;
const W: usize = 4;
const LR: f32 = 0.05;
const SEED: u64 = 0xABCD;

/// Hand-rolled worker: handshake, take the one assigned slot, wait for
/// `gate` (None = no wait), upload, drain round-end + shutdown.
fn worker(ep: &Endpoint, gate: Option<mpsc::Receiver<()>>) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let (bytes, _) = read_msg(&mut conn, 64 << 20).unwrap();
    let (seed, assignments) = match Msg::decode(bytes).unwrap() {
        Msg::RoundStart { round_seed, assignments, .. } => (round_seed, assignments),
        _ => panic!("expected round-start"),
    };
    if let Some(rx) = gate {
        rx.recv_timeout(Duration::from_secs(30)).expect("straggler gate never released");
    }
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        write_msg(&mut conn, &Msg::Upload { slot, loss: 0.5, frame }.encode()).unwrap();
    }
    loop {
        let (bytes, _) = read_msg(&mut conn, 64 << 20).unwrap();
        match Msg::decode(bytes).unwrap() {
            Msg::RoundEnd { .. } => {}
            Msg::Shutdown => break,
            other => panic!("unexpected {}", other.kind_name()),
        }
    }
}

#[test]
fn straggler_does_not_block_streaming_absorb() {
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let probe = srv.absorbed_probe();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|s| {
        // Three prompt workers and one gated straggler.
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || worker(&ep, Some(rx)));

        // The round runs on its own thread so this one can watch the
        // probe while the straggler is still withholding its upload.
        let server_round = s.spawn(|| {
            let params = RoundParams {
                round: 0,
                round_seed: SEED,
                lr: LR,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
            srv.shutdown();
            stats
        });

        // Streaming absorb, observed: all prompt uploads must fold in
        // while the straggler is provably still waiting on our gate.
        let deadline = Instant::now() + Duration::from_secs(20);
        while probe.load(Ordering::SeqCst) < W - 1 {
            assert!(Instant::now() < deadline, "prompt uploads were not absorbed while waiting");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            probe.load(Ordering::SeqCst),
            W - 1,
            "the withheld upload cannot have been absorbed"
        );
        // Release the straggler; the round must now complete.
        tx.send(()).unwrap();
        let stats = server_round.join().expect("server round panicked");
        assert_eq!(stats.losses.len(), W);
        assert_eq!(probe.load(Ordering::SeqCst), W);
    });

    // Streaming changed latency, never bits.
    let uploads: Vec<ClientUpload> = participants
        .iter()
        .map(|&c| ClientUpload::Dense(synth_grad(DIM, HEAVY, c, SEED)))
        .collect();
    let mut w_ref = vec![0f32; DIM];
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    run_server_round(&mut agg_ref, &sizes, uploads, &mut w_ref, LR).unwrap();
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w));
}

/// A straggler worker that withholds its upload until the gate opens
/// and tolerates every error afterwards — under a round deadline the
/// server legitimately drops its connection before it ever uploads.
fn tolerant_straggler(ep: &Endpoint, rx: mpsc::Receiver<()>) {
    let mut conn = Conn::connect(ep).unwrap();
    conn.set_timeouts(Some(Duration::from_secs(30)), Some(Duration::from_secs(30))).unwrap();
    write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode()).unwrap();
    let Ok((bytes, _)) = read_msg(&mut conn, 64 << 20) else { return };
    let (seed, assignments) = match Msg::decode(bytes) {
        Ok(Msg::RoundStart { round_seed, assignments, .. }) => (round_seed, assignments),
        _ => return,
    };
    let _ = rx.recv_timeout(Duration::from_secs(30));
    for (slot, client) in assignments {
        let g = synth_grad(DIM, HEAVY, client as usize, seed);
        let frame = encode_upload(&ClientUpload::Dense(g), &F32LE);
        let _ = write_msg(&mut conn, &Msg::Upload { slot, loss: 0.5, frame }.encode());
    }
}

/// Quorum counterpart of the probe test: with `round_deadline_ms` set
/// and `quorum_fraction = 0.5`, the round *completes* once the deadline
/// fires — the gated straggler is dropped, not waited for, and the
/// merged weights equal a finalize-at-quorum reference over the same
/// surviving membership set, bit for bit.
#[test]
fn straggler_past_deadline_is_dropped_at_quorum() {
    let policy = QuorumPolicy::new(0.5, 2000, 0).unwrap();
    let opts = ServeOptions {
        workers: W,
        read_timeout: Duration::from_secs(30),
        accept_timeout: Duration::from_secs(30),
        quorum: policy.clone(),
        ..Default::default()
    };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let mut agg = UncompressedServer::new(DIM, 0.0);
    let mut w = vec![0f32; DIM];
    let participants: Vec<usize> = (0..W).collect();
    let sizes = vec![1.0f32; W];
    let (tx, rx) = mpsc::channel();

    let stats = std::thread::scope(|s| {
        for _ in 0..W - 1 {
            let ep = actual.clone();
            s.spawn(move || worker(&ep, None));
        }
        let ep = actual.clone();
        s.spawn(move || tolerant_straggler(&ep, rx));
        let params = RoundParams {
            round: 0,
            round_seed: SEED,
            lr: LR,
            participants: &participants,
            client_sizes: &sizes,
        };
        let stats = srv.run_round(&mut agg, &params, &mut w).unwrap();
        srv.shutdown();
        // Only now may the straggler move — the round closed without
        // it.
        tx.send(()).ok();
        stats
    });

    assert_eq!(stats.participants, W - 1, "round completes with the arrived subset");
    assert_eq!(stats.dropped_slots, 1, "the straggler's slot is dropped");
    assert_eq!(stats.retried_slots, 0);
    assert!(w.iter().any(|&x| x != 0.0), "the partial round still steps the model");

    // The straggler's slot is the one that reported no loss.
    let dropped_slot = stats.losses.iter().position(|&l| l == 0.0).expect("one dropped slot");

    // Finalize-at-quorum reference over the same surviving set.
    let mut agg_ref = UncompressedServer::new(DIM, 0.0);
    let lambdas = agg_ref.begin_round(&sizes);
    let spec: UploadSpec = agg_ref.upload_spec();
    let mut pl = RoundPipeline::new(PipelineOptions::default());
    let mut m = RoundMembership::new(W, policy).unwrap();
    let mut r = pl.begin(&spec, lambdas).unwrap();
    for slot in 0..W {
        if slot == dropped_slot {
            continue;
        }
        let g = synth_grad(DIM, HEAVY, participants[slot], SEED);
        r.offer(slot, ClientUpload::Dense(g)).unwrap();
        m.record_arrival(slot);
    }
    m.record_drop(dropped_slot, DropReason::Deadline);
    let merged = pl.finalize_partial(r, &m).unwrap();
    let update = agg_ref.finish(&merged, LR).unwrap();
    let mut w_ref = vec![0f32; DIM];
    update.apply(&mut w_ref);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&w_ref), bits(&w), "deadline drop changed the surviving slots' math");
}
