//! Transport determinism: serving rounds over a real socket must be a
//! pure deployment knob. A multi-round run with N socket workers over
//! UDS (and TCP) produces bitwise-identical final weights and losses to
//! the in-process engine at parallelism 1 and 8, for the sketch,
//! sparse, and dense upload paths — the acceptance bar for the
//! transport subsystem.
//!
//! Why this holds: server and engine drive the *same*
//! `aggregate::RoundPipeline` — one shard layout, an in-flight round
//! that enforces in-shard slot order no matter when frames arrive, one
//! row-strip shard reduction — weights broadcasts are lossless `f32le`,
//! and the update round-trips encode→decode exactly like wire mode
//! (itself pinned bitwise-identical in `parallel_determinism.rs`).

use std::sync::Arc;
use std::time::Duration;

use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::local_topk::LocalTopKServer;
use fetchsgd::compression::sim::{
    sim_artifacts, SimDataset, SimDenseClient, SimSketchClient, SimTopKClient,
};
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientCompute, ServerAggregator};
use fetchsgd::coordinator::{engine, ClientSelector};
use fetchsgd::trace::TraceSink;
use fetchsgd::transport::{join, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions};
use fetchsgd::util::rng::derive_seed;
use fetchsgd::wire::Codec;

const DIM: usize = 30_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 0xD5;
const ROUNDS: usize = 4;
const COHORT: usize = 24; // > MAX_SHARDS ⇒ shards own multiple slots
const NUM_CLIENTS: usize = 200;

/// The in-process reference loop — the engine pipeline exactly as the
/// Trainer drives it (mirrors `parallel_determinism.rs::sim_train`).
fn sim_train(
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    threads: usize,
    wire: Option<&'static dyn Codec>,
) -> (Vec<f32>, Vec<f32>, u64) {
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
    let dataset = SimDataset { num_clients: NUM_CLIENTS };
    let selector = ClientSelector::new(NUM_CLIENTS, COHORT, SEED);
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut wire_upload_bytes = 0u64;
    let policy = fetchsgd::cohort::QuorumPolicy::strict();
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.05,
            round_seed: derive_seed(SEED, round as u64),
            threads,
            wire,
            policy: &policy,
            round: round as u64,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .unwrap();
        losses.extend_from_slice(&out.losses);
        wire_upload_bytes += out.wire_upload_bytes_per_client * participants.len() as u64;
        let update = server.finish(&out.merged, 0.05).unwrap();
        pipeline.recycle(out.merged);
        let update = match wire {
            Some(codec) => {
                let frame = fetchsgd::wire::encode_update(&update, codec);
                fetchsgd::wire::decode_update(&frame).unwrap()
            }
            None => update,
        };
        update.apply(&mut w);
    }
    (w, losses, wire_upload_bytes)
}

/// The same training loop served over a socket: the server side runs
/// `RoundServer::run_round` per round while `workers` socket clients
/// drive the client compute through `transport::join`.
fn transport_train(
    ep: &Endpoint,
    workers: usize,
    client: &dyn ClientCompute,
    server: &mut dyn ServerAggregator,
    trace: Option<Arc<TraceSink>>,
) -> (Vec<f32>, Vec<f32>, u64) {
    let opts = ServeOptions {
        workers,
        read_timeout: Duration::from_secs(60),
        accept_timeout: Duration::from_secs(60),
        trace,
        ..Default::default()
    };
    let mut srv = RoundServer::bind(ep, opts).unwrap();
    let actual = srv.local_endpoint().unwrap();
    let selector = ClientSelector::new(NUM_CLIENTS, COHORT, SEED);
    let mut w = vec![0f32; DIM];
    let mut losses = Vec::new();
    let mut wire_upload_bytes = 0u64;
    std::thread::scope(|s| {
        for _ in 0..workers {
            let ep = actual.clone();
            s.spawn(move || {
                let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                let dataset = SimDataset { num_clients: NUM_CLIENTS };
                let opts = JoinOptions {
                    read_timeout: Some(Duration::from_secs(60)),
                    ..Default::default()
                };
                let sum = join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                assert_eq!(sum.rounds, ROUNDS);
                assert!(sum.uploads > 0);
            });
        }
        for round in 0..ROUNDS {
            let participants = selector.select(round);
            let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
            let params = RoundParams {
                round: round as u64,
                round_seed: derive_seed(SEED, round as u64),
                lr: 0.05,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(server, &params, &mut w).unwrap();
            assert_eq!(stats.losses.len(), participants.len());
            wire_upload_bytes += stats.wire_upload_bytes_per_client * participants.len() as u64;
            losses.extend_from_slice(&stats.losses);
        }
        srv.shutdown();
    });
    (w, losses, wire_upload_bytes)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[cfg(unix)]
fn uds_endpoint(tag: &str) -> Endpoint {
    let path = std::env::temp_dir().join(format!("fsgw_{}_{tag}.sock", std::process::id()));
    Endpoint::Unix(path)
}

type ServerFactory = Box<dyn Fn() -> Box<dyn ServerAggregator>>;

fn strategies() -> Vec<(&'static str, Box<dyn ClientCompute>, ServerFactory)> {
    vec![
        (
            "fetchsgd",
            Box::new(SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 4 }),
            Box::new(|| {
                Box::new(
                    FetchSgdServer::new(
                        ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
                    )
                    .unwrap(),
                ) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "local_topk",
            Box::new(SimTopKClient { dim: DIM, heavy: 4, k: 40 }),
            Box::new(|| {
                Box::new(LocalTopKServer::new(DIM, 0.9, false)) as Box<dyn ServerAggregator>
            }),
        ),
        (
            "uncompressed",
            Box::new(SimDenseClient { dim: DIM, heavy: 4 }),
            Box::new(|| Box::new(UncompressedServer::new(DIM, 0.9)) as Box<dyn ServerAggregator>),
        ),
    ]
}

/// Acceptance: a full multi-round run over UDS with 3 socket workers is
/// bitwise identical to the in-process engine at parallelism 1 and 8,
/// for sketch, sparse, and dense upload paths.
#[cfg(unix)]
#[test]
fn uds_serve_join_is_bitwise_identical_to_in_process() {
    for (name, client, make_server) in &strategies() {
        let (w1, l1, _) = sim_train(client.as_ref(), make_server().as_mut(), 1, None);
        assert!(w1.iter().any(|&x| x != 0.0), "{name}: training must move the model");
        for threads in [3usize, 8] {
            let (wn, ln, _) = sim_train(client.as_ref(), make_server().as_mut(), threads, None);
            assert_eq!(bits(&w1), bits(&wn), "{name}: in-process p1 vs p{threads} diverged");
            assert_eq!(bits(&l1), bits(&ln), "{name}: losses diverge at parallelism {threads}");
        }
        let ep = uds_endpoint(name);
        let (wt, lt, _) = transport_train(&ep, 3, client.as_ref(), make_server().as_mut(), None);
        assert_eq!(bits(&w1), bits(&wt), "{name}: transport weights diverge from in-process");
        assert_eq!(bits(&l1), bits(&lt), "{name}: transport losses diverge from in-process");
    }
}

/// The same loopback round over TCP, plus measured-frame-byte parity
/// with in-process wire mode (the transport carries exactly the frames
/// wire mode accounts for).
#[test]
fn tcp_serve_join_matches_in_process_and_wire_accounting() {
    let strategies = strategies();
    let (name, client, make_server) = &strategies[0];
    let (w1, l1, _) = sim_train(client.as_ref(), make_server().as_mut(), 1, None);
    let (_, _, wire_bytes_mem) =
        sim_train(client.as_ref(), make_server().as_mut(), 1, Some(&fetchsgd::wire::F32LE));
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let (wt, lt, wire_bytes_net) =
        transport_train(&ep, 2, client.as_ref(), make_server().as_mut(), None);
    assert_eq!(bits(&w1), bits(&wt), "{name}: tcp transport weights diverge");
    assert_eq!(bits(&l1), bits(&lt), "{name}: tcp transport losses diverge");
    assert_eq!(
        wire_bytes_mem, wire_bytes_net,
        "{name}: measured frame bytes differ between wire mode and transport"
    );
}

/// A served run with a root-tier `TraceSink` attached is bitwise
/// identical to the untraced in-process reference, and the trace it
/// writes reconstructs the transport timeline: the five server phases,
/// one `offered` per slot, per-connection IO splits, and an exact
/// per-round arrival histogram.
#[test]
fn tracing_is_bitwise_neutral_over_transport() {
    use fetchsgd::trace::summary::fold_files;

    let strategies = strategies();
    let (name, client, make_server) = &strategies[0];
    let (w1, l1, _) = sim_train(client.as_ref(), make_server().as_mut(), 1, None);

    let dir = std::env::temp_dir().join(format!("fsgd_td_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("root.jsonl");
    let sink = Arc::new(TraceSink::create(&path, "root", "tcp:loopback").unwrap());
    let ep = Endpoint::Tcp("127.0.0.1:0".into());
    let (wt, lt, _) =
        transport_train(&ep, 2, client.as_ref(), make_server().as_mut(), Some(sink.clone()));
    sink.flush().unwrap();

    assert_eq!(bits(&w1), bits(&wt), "{name}: tracing perturbed the served weights");
    assert_eq!(bits(&l1), bits(&lt), "{name}: tracing perturbed the served losses");

    let report = fold_files(&[&path]).unwrap();
    assert_eq!(report.unknown_lines, 0);
    assert_eq!(report.rounds.len(), ROUNDS);
    let root = "root".to_string();
    for (round, tl) in &report.rounds {
        for phase in ["plan", "absorb_wait", "finalize", "reduce", "broadcast"] {
            assert!(
                tl.phases.contains_key(&(root.clone(), phase.to_string())),
                "round {round} missing root-tier {phase} span"
            );
        }
        assert_eq!(tl.events[&(root.clone(), "offered".to_string())], COHORT as u64);
    }
    // Both worker connections reported their IO split every round.
    let peers: Vec<u64> =
        report.conn_totals.keys().filter(|(t, _)| *t == root).map(|&(_, p)| p).collect();
    assert_eq!(peers, [0, 1], "expected IO totals for exactly two connections");
    let h = &report.hists[&(root.clone(), "slot_arrival_us".to_string())];
    assert_eq!(h.count(), (ROUNDS * COHORT) as u64, "one arrival sample per slot per round");
    std::fs::remove_dir_all(&dir).ok();
}
