"""Pallas Count-Sketch *encode* kernel: ``S(g)``, (d,) -> (rows, cols).

This is FetchSGD's client-side compute hot-spot: every participating
client sketches its gradient every round, inside the same HLO graph that
computes the gradient (see ``compile/model.py``), so the sketch rides the
AOT artifact and Python never touches the training path.

Hardware adaptation (DESIGN.md §2): the reference implementation computes
the sketch with CUDA atomic scatter-adds. Scatter is hostile to the TPU
MXU, so the TPU formulation is a *blocked one-hot matmul*: for a gradient
block ``g_b`` of size B, each sketch row's update is

    table[r] += (sign_r ⊙ g_b)ᵀ · onehot(bucket_r)        # (1,B)·(B,C)

an MXU-shaped contraction whose operands are built in VMEM from the hash
constants — no B×C matrix ever touches HBM. The BlockSpec streams ``g``
HBM→VMEM in blocks of ``block``; the (rows, cols) table is the VMEM
accumulator, legal because every grid step maps to the same output block.

Two in-kernel strategies, selected by ``strategy``:

- ``"onehot"`` — the MXU formulation above, tiled over columns
  (``col_tile``) to bound VMEM. This is the shape that runs fast on real
  TPU hardware.
- ``"scatter"`` — per-row in-kernel segment-sum. Under ``interpret=True``
  on CPU (the only execution mode available in this environment — real
  TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
  run), XLA compiles this to a serial scatter-add which is dramatically
  cheaper than emulating the one-hot matmul; it is therefore the default
  for the shipped artifacts. Both strategies are verified against
  ``ref.py`` by pytest.

VMEM footprint (onehot): ``block + rows*cols + block*col_tile`` f32.
With block=2048, rows=5, cols=2^16, col_tile=512: ~5.5 MB — comfortably
inside a TPU core's ~16 MB VMEM with room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hashing import SketchHasher


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _encode_kernel_onehot(g_ref, o_ref, *, h: SketchHasher, block: int, col_tile: int):
    """One grid step: fold one gradient block into the sketch table."""
    pi = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    base = (pi * block).astype(jnp.uint32)
    idx = base + jnp.arange(block, dtype=jnp.uint32)
    gb = g_ref[...].astype(jnp.float32)
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)  # (block,) int32
        signed = h.sign_jnp(r, idx) * gb  # (block,)
        # Tile the one-hot contraction over columns to bound VMEM.
        for c0 in range(0, h.cols, col_tile):
            cols_tile = c0 + jnp.arange(col_tile, dtype=jnp.int32)
            onehot = (buckets[:, None] == cols_tile[None, :]).astype(jnp.float32)
            # (1,B) @ (B,Ct) on the MXU.
            contrib = signed[None, :] @ onehot  # (1, col_tile)
            o_ref[r, c0 : c0 + col_tile] += contrib[0]


def _encode_kernel_scatter(g_ref, o_ref, *, h: SketchHasher, block: int):
    """One grid step, scatter formulation (CPU-friendly under interpret)."""
    pi = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    base = (pi * block).astype(jnp.uint32)
    idx = base + jnp.arange(block, dtype=jnp.uint32)
    gb = g_ref[...].astype(jnp.float32)
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)
        signed = h.sign_jnp(r, idx) * gb
        row = jax.ops.segment_sum(signed, buckets, num_segments=h.cols)
        o_ref[r, :] += row


@functools.partial(
    jax.jit, static_argnames=("h", "block", "col_tile", "strategy", "interpret")
)
def sketch_encode(
    g: jnp.ndarray,
    *,
    h: SketchHasher,
    block: int = 2048,
    col_tile: int = 512,
    strategy: str = "scatter",
    interpret: bool = True,
) -> jnp.ndarray:
    """Sketch a flat vector: returns the (rows, cols) f32 table.

    ``g`` is zero-padded to a multiple of ``block``; padded coordinates
    contribute exactly 0 to every bucket, so no masking is needed.
    """
    assert g.ndim == 1, f"sketch_encode expects a flat vector, got {g.shape}"
    d = g.shape[0]
    dp = _ceil_to(max(d, 1), block)
    if dp != d:
        g = jnp.pad(g, (0, dp - d))
    grid = (dp // block,)
    if strategy == "onehot":
        ct = min(col_tile, h.cols)
        kernel = functools.partial(_encode_kernel_onehot, h=h, block=block, col_tile=ct)
    elif strategy == "scatter":
        kernel = functools.partial(_encode_kernel_scatter, h=h, block=block)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((h.rows, h.cols), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((h.rows, h.cols), jnp.float32),
        interpret=interpret,
    )(g)
