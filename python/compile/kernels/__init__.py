# L1: Pallas Count-Sketch kernels + pure-jnp oracle + shared hash spec.
from .count_sketch import sketch_encode  # noqa: F401
from .hashing import SketchHasher  # noqa: F401
from .unsketch import unsketch_estimate  # noqa: F401
