"""Pallas Count-Sketch *estimate* kernel: ``U(S)``, (rows, cols) -> (d,).

The decompression direction: per coordinate, the median over sketch rows
of ``sign_r(i) * table[r, bucket_r(i)]``. In FetchSGD the server performs
this every round before Top-k; the Rust coordinator has its own
implementation (``rust/src/sketch``), but this kernel ships so that
end-to-end *device-side* pipelines (e.g. evaluating Δ on-device, or
running the whole server update as one HLO) are possible, and to complete
the L1 kernel pair verified against ``ref.py``.

Blocking: grid over d-blocks; the full (rows, cols) table is broadcast to
every grid step (constant index_map) and stays resident in VMEM — it is
small (rows·cols ≤ a few MB) by construction of the compression argument.
Per block we gather the R candidate estimates and reduce with a sorting
network over the row axis (R is a small static constant, so the "median"
is a fixed sequence of min/max ops — no data-dependent control flow).

Strategies mirror the encode kernel: ``"gather"`` (CPU-friendly dynamic
gather) and ``"onehot"`` (MXU-shaped: estimates_r = onehot(bucket_r) ·
table_r, a (B,C)·(C,) contraction, tiled over columns).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hashing import SketchHasher


def _median_static(stack: jnp.ndarray) -> jnp.ndarray:
    """Median over axis 0 for a small static row count (sorted reduce)."""
    r = stack.shape[0]
    s = jnp.sort(stack, axis=0)
    if r % 2 == 1:
        return s[r // 2]
    return 0.5 * (s[r // 2 - 1] + s[r // 2])


def _estimate_kernel_gather(t_ref, o_ref, *, h: SketchHasher, block: int):
    pi = pl.program_id(0)
    base = (pi * block).astype(jnp.uint32)
    idx = base + jnp.arange(block, dtype=jnp.uint32)
    per_row = []
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)
        signs = h.sign_jnp(r, idx)
        row = t_ref[r, :]
        per_row.append(signs * row[buckets])
    o_ref[...] = _median_static(jnp.stack(per_row, axis=0))


def _estimate_kernel_onehot(t_ref, o_ref, *, h: SketchHasher, block: int, col_tile: int):
    pi = pl.program_id(0)
    base = (pi * block).astype(jnp.uint32)
    idx = base + jnp.arange(block, dtype=jnp.uint32)
    per_row = []
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)
        signs = h.sign_jnp(r, idx)
        acc = jnp.zeros((block,), jnp.float32)
        for c0 in range(0, h.cols, col_tile):
            cols_tile = c0 + jnp.arange(col_tile, dtype=jnp.int32)
            onehot = (buckets[:, None] == cols_tile[None, :]).astype(jnp.float32)
            acc = acc + onehot @ t_ref[r, c0 : c0 + col_tile]
        per_row.append(signs * acc)
    o_ref[...] = _median_static(jnp.stack(per_row, axis=0))


@functools.partial(
    jax.jit, static_argnames=("h", "d", "block", "col_tile", "strategy", "interpret")
)
def unsketch_estimate(
    table: jnp.ndarray,
    *,
    h: SketchHasher,
    d: int,
    block: int = 2048,
    col_tile: int = 512,
    strategy: str = "gather",
    interpret: bool = True,
) -> jnp.ndarray:
    """Estimate all ``d`` coordinates from a (rows, cols) sketch table."""
    assert table.shape == (h.rows, h.cols), (table.shape, (h.rows, h.cols))
    dp = (max(d, 1) + block - 1) // block * block
    grid = (dp // block,)
    if strategy == "gather":
        kernel = functools.partial(_estimate_kernel_gather, h=h, block=block)
    elif strategy == "onehot":
        ct = min(col_tile, h.cols)
        kernel = functools.partial(_estimate_kernel_onehot, h=h, block=block, col_tile=ct)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    est = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((h.rows, h.cols), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(table.astype(jnp.float32))
    return est[:d]
