"""Pure-jnp Count-Sketch oracle — the correctness reference for the
Pallas kernels (L1) and, transitively, for the Rust implementation
(pinned by the golden hash vectors plus the artifact integration test).

Everything here is straightforward segment-sum / gather code with no
blocking or kernel tricks; pytest asserts the Pallas kernels match this
module to float tolerance across shapes and seeds.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .hashing import SketchHasher


def sketch_encode_ref(h: SketchHasher, g: jnp.ndarray) -> jnp.ndarray:
    """``S(g)``: (d,) -> (rows, cols) via per-row signed segment-sum."""
    d = g.shape[0]
    idx = jnp.arange(d, dtype=jnp.uint32)
    rows = []
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)
        signs = h.sign_jnp(r, idx)
        rows.append(jax.ops.segment_sum(signs * g, buckets, num_segments=h.cols))
    return jnp.stack(rows, axis=0)


def unsketch_estimate_ref(h: SketchHasher, table: jnp.ndarray, d: int) -> jnp.ndarray:
    """``U(S)``: (rows, cols) -> (d,) estimates; median over rows of
    ``sign_r(i) * table[r, bucket_r(i)]``."""
    idx = jnp.arange(d, dtype=jnp.uint32)
    per_row = []
    for r in range(h.rows):
        buckets = h.bucket_jnp(r, idx)
        signs = h.sign_jnp(r, idx)
        per_row.append(signs * table[r, buckets])
    stacked = jnp.stack(per_row, axis=0)  # (rows, d)
    return jnp.median(stacked, axis=0)


def top_k_ref(est: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k by magnitude: returns (indices, values)."""
    _, idx = jax.lax.top_k(jnp.abs(est), k)
    return idx, est[idx]
