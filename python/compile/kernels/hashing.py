"""Count-Sketch hash spec — the Python half of the cross-language contract.

Mirrors ``rust/src/hashing.rs`` bit-for-bit. Both sides derive per-row
u32 constants from a master u64 seed via splitmix64 and hash coordinate
indices with u32 wrapping multiply-shift:

    bucket_r(i) = ((a_b * i + b_b) mod 2**32) >> (32 - log2(C))
    sign_r(i)   = +1 if top bit of ((a_s * i + b_s) mod 2**32) == 0 else -1

``C`` (columns) must be a power of two. All jnp arithmetic is uint32,
whose wrapping semantics match Rust's ``u32``. Changing anything here is
a breaking change to every artifact — bump SPEC_VERSION in both
languages and re-run ``make artifacts``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

SPEC_VERSION = 1

_MASK64 = (1 << 64) - 1


def splitmix64(state: int) -> tuple[int, int]:
    """One splitmix64 step: returns (value, new_state). Pure-int mirror of
    the Rust implementation (no numpy overflow concerns)."""
    state = (state + 0x9E3779B97F4A7C15) & _MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (z ^ (z >> 31)) & _MASK64, state


@dataclasses.dataclass(frozen=True)
class RowHash:
    a_bucket: int
    b_bucket: int
    a_sign: int
    b_sign: int


@dataclasses.dataclass(frozen=True)
class SketchHasher:
    """Hash parameterization for an R x C Count Sketch."""

    rows: int
    cols: int
    seed: int
    row_hashes: tuple[RowHash, ...]

    @staticmethod
    def create(rows: int, cols: int, seed: int) -> "SketchHasher":
        assert rows >= 1, "rows must be >= 1"
        assert cols >= 2 and (cols & (cols - 1)) == 0, f"cols must be a power of two >= 2, got {cols}"
        assert cols <= 1 << 31
        state = seed & _MASK64
        row_hashes = []
        for _ in range(rows):
            v, state = splitmix64(state)
            a_bucket = (v & 0xFFFFFFFF) | 1
            v, state = splitmix64(state)
            b_bucket = v & 0xFFFFFFFF
            v, state = splitmix64(state)
            a_sign = (v & 0xFFFFFFFF) | 1
            v, state = splitmix64(state)
            b_sign = v & 0xFFFFFFFF
            row_hashes.append(RowHash(a_bucket, b_bucket, a_sign, b_sign))
        return SketchHasher(rows, cols, seed, tuple(row_hashes))

    @property
    def shift(self) -> int:
        return 32 - int(self.cols).bit_length() + 1  # 32 - log2(cols)

    def bucket_np(self, r: int, idx: np.ndarray) -> np.ndarray:
        """Reference (numpy) bucket hash for index array ``idx`` (uint32)."""
        h = self.row_hashes[r]
        i = idx.astype(np.uint64)
        v = (np.uint64(h.a_bucket) * i + np.uint64(h.b_bucket)) & np.uint64(0xFFFFFFFF)
        return (v >> np.uint64(self.shift)).astype(np.int64)

    def sign_np(self, r: int, idx: np.ndarray) -> np.ndarray:
        h = self.row_hashes[r]
        i = idx.astype(np.uint64)
        v = (np.uint64(h.a_sign) * i + np.uint64(h.b_sign)) & np.uint64(0xFFFFFFFF)
        return np.where((v >> np.uint64(31)) & np.uint64(1), -1.0, 1.0).astype(np.float32)

    def bucket_jnp(self, r: int, idx: jnp.ndarray) -> jnp.ndarray:
        """uint32 wrapping bucket hash (traceable; used inside kernels)."""
        h = self.row_hashes[r]
        i = idx.astype(jnp.uint32)
        v = jnp.uint32(h.a_bucket) * i + jnp.uint32(h.b_bucket)
        return (v >> jnp.uint32(self.shift)).astype(jnp.int32)

    def sign_jnp(self, r: int, idx: jnp.ndarray) -> jnp.ndarray:
        h = self.row_hashes[r]
        i = idx.astype(jnp.uint32)
        v = jnp.uint32(h.a_sign) * i + jnp.uint32(h.b_sign)
        return jnp.where(v >> jnp.uint32(31), -1.0, 1.0).astype(jnp.float32)

    def to_manifest(self) -> dict:
        """Entry recorded in artifacts/manifest.json (Rust re-derives the
        constants from (rows, cols, seed) and checks SPEC_VERSION)."""
        return {
            "rows": self.rows,
            "cols": self.cols,
            "seed": self.seed,
            "spec_version": SPEC_VERSION,
        }
