"""AOT pipeline: lower every (task, computation) pair to HLO text and
emit ``artifacts/manifest.json`` + initial weights.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts`` (no-op when inputs are unchanged) or:

    cd python && python -m compile.aot --out-dir ../artifacts [--tasks smoke,...]

Python runs only here, at build time; the Rust coordinator is
self-contained once artifacts exist.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import struct
import sys

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.hashing import SPEC_VERSION, SketchHasher
from .model import make_client_grad, make_client_step, make_eval_step, make_fedavg_step
from .models import make_cnn, make_mlp, make_transformer

SKETCH_ROWS = 5
WEIGHT_SEED = 0xF5_2020  # init seed; recorded in the manifest

# ---------------------------------------------------------------------------
# Task definitions. `sketch_cols` lists the column counts to bake one
# FetchSGD client_step artifact each (the fig3/4/5 compression sweeps);
# `fedavg_steps` lists the local-step counts for FedAvg artifacts.
# `data` describes the synthetic dataset the Rust side must generate.
# ---------------------------------------------------------------------------


def _tasks() -> dict:
    return {
        "smoke": {
            "model": lambda: make_mlp(
                "mlp_smoke", input_shape=(8, 8, 1), num_classes=10, hidden=(32,), batch=4
            ),
            "sketch_cols": [512],
            "fedavg_steps": [2],
            "sketch_seed": 0x51E7C4,
            "data": {"kind": "images", "image": [8, 8, 1], "classes": 10},
        },
        "cifar10": {
            "model": lambda: make_cnn(
                "cnn_cifar10", image=(16, 16, 3), num_classes=10, widths=(16, 32, 64), batch=16
            ),
            "sketch_cols": [2048, 4096, 8192, 16384],
            "fedavg_steps": [2, 5],
            "sketch_seed": 0xC1FA10,
            "data": {"kind": "images", "image": [16, 16, 3], "classes": 10},
        },
        "cifar100": {
            "model": lambda: make_cnn(
                "cnn_cifar100", image=(16, 16, 3), num_classes=100, widths=(16, 32, 64), batch=16
            ),
            "sketch_cols": [2048, 4096, 8192, 16384],
            "fedavg_steps": [2, 5],
            "sketch_seed": 0xC1FA64,
            "data": {"kind": "images", "image": [16, 16, 3], "classes": 100},
        },
        "femnist": {
            "model": lambda: make_mlp(
                "mlp_femnist", input_shape=(16, 16, 1), num_classes=32, hidden=(128, 64), batch=20
            ),
            "sketch_cols": [1024, 2048, 4096, 8192],
            "fedavg_steps": [1, 2, 5],
            "sketch_seed": 0xFE301,
            "data": {"kind": "images", "image": [16, 16, 1], "classes": 32},
        },
        "persona": {
            "model": lambda: make_transformer(
                "tfm_persona", vocab=64, seq=32, dim=64, heads=4, layers=2, batch=8
            ),
            "sketch_cols": [1024, 4096, 16384],
            "fedavg_steps": [2, 5],
            "sketch_seed": 0x9E850,
            "data": {"kind": "text", "vocab": 64, "seq": 32},
        },
        "persona_large": {
            # e2e-driver scale: the largest model the CPU PJRT substrate
            # trains in reasonable wallclock (GPT2-124M substitute).
            "model": lambda: make_transformer(
                "tfm_persona_large", vocab=96, seq=64, dim=128, heads=8, layers=4, batch=8
            ),
            "sketch_cols": [16384, 65536],
            "fedavg_steps": [2],
            "sketch_seed": 0x9E851,
            "data": {"kind": "text", "vocab": 96, "seq": 64},
        },
    }


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

_DTYPES = {"f32": np.float32, "i32": np.int32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(shape, _DTYPES[dtype])


def _model_inputs(model):
    xs, xd = model.input_spec["x"]
    ys, yd = model.input_spec["y"]
    ms, md = model.input_spec["mask"]
    return _spec(xs, xd), _spec(ys, yd), _spec(ms, md)


def write_weights_bin(path: pathlib.Path, w: np.ndarray) -> None:
    """Same header as rust/src/serialize/bin.rs: magic + u64 LE count."""
    with open(path, "wb") as f:
        f.write(b"FSGDF32\0")
        f.write(struct.pack("<Q", w.size))
        f.write(w.astype("<f4").tobytes())


def lower_task(name: str, cfg: dict, out_dir: pathlib.Path, manifest: dict) -> None:
    model = cfg["model"]()
    d = model.dim
    w_spec = _spec((d,))
    x_s, y_s, m_s = _model_inputs(model)
    print(f"[aot] task {name}: model={model.name} d={d}")

    entry = {
        "name": name,
        "model": model.name,
        "dim": d,
        "batch": model.input_spec["x"][0][0],
        "input_spec": {k: {"shape": list(v[0]), "dtype": v[1]} for k, v in model.input_spec.items()},
        "data": cfg["data"],
        "weight_seed": WEIGHT_SEED,
        "init_weights": f"{name}_init.bin",
        "artifacts": {},
        "sketch": {"rows": SKETCH_ROWS, "seed": cfg["sketch_seed"], "cols": cfg["sketch_cols"],
                   "spec_version": SPEC_VERSION},
        "fedavg_steps": cfg["fedavg_steps"],
    }

    # Initial weights.
    w0 = model.init_flat(WEIGHT_SEED)
    assert w0.size == d
    write_weights_bin(out_dir / entry["init_weights"], w0)

    def emit(kind: str, fn, args) -> None:
        fname = f"{name}_{kind}.hlo.txt"
        lowered = jax.jit(fn).lower(*args)
        (out_dir / fname).write_text(to_hlo_text(lowered))
        entry["artifacts"][kind] = fname
        print(f"[aot]   {fname}")

    # FetchSGD client step, one per sketch width.
    for cols in cfg["sketch_cols"]:
        hasher = SketchHasher.create(SKETCH_ROWS, cols, cfg["sketch_seed"])
        emit(f"client_step_c{cols}", make_client_step(model, hasher), (w_spec, x_s, y_s, m_s))

    # Baseline gradient, eval, FedAvg.
    emit("client_grad", make_client_grad(model), (w_spec, x_s, y_s, m_s))
    emit("eval", make_eval_step(model), (w_spec, x_s, y_s, m_s))
    for k in cfg["fedavg_steps"]:
        xs = _spec((k, *x_s.shape), "f32" if x_s.dtype == np.float32 else "i32")
        ys = _spec((k, *y_s.shape), "i32")
        ms = _spec((k, *m_s.shape), "f32")
        lr = _spec((), "f32")
        emit(f"fedavg_k{k}", make_fedavg_step(model, k), (w_spec, xs, ys, ms, lr))

    manifest["tasks"].append(entry)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--tasks", default="all", help="comma list or 'all'")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    tasks = _tasks()
    selected = list(tasks) if args.tasks == "all" else args.tasks.split(",")
    for t in selected:
        if t not in tasks:
            sys.exit(f"unknown task '{t}' (have: {', '.join(tasks)})")

    manifest = {"spec_version": SPEC_VERSION, "sketch_rows": SKETCH_ROWS, "tasks": []}
    for t in selected:
        lower_task(t, tasks[t], out_dir, manifest)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {out_dir / 'manifest.json'} ({len(manifest['tasks'])} tasks)")


if __name__ == "__main__":
    main()
