# L2 model zoo: flat-weight-vector models used by the federated tasks.
from .common import FlatModel, ParamSpec  # noqa: F401
from .mlp import make_mlp  # noqa: F401
from .cnn import make_cnn  # noqa: F401
from .transformer import make_transformer  # noqa: F401
