"""Small convolutional classifier with GroupNorm — the ResNet9 analog.

The paper trains a modified ResNet9 (Page 2019) *without batch norm*
because per-client batches are tiny (1–5 images); we keep that property
with GroupNorm. Architecture (configurable widths):

    conv3x3(C0) GN relu → conv3x3(C1) GN relu → pool2
    → residual block [conv3x3(C1) GN relu ×2 + skip]
    → conv3x3(C2) GN relu → pool2 → residual block(C2)
    → global-avg-pool → dense(num_classes)

All convs are SAME-padded NHWC.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import FlatModel, ParamSpec, masked_ce_from_logits, mean_masked_loss


def _conv(x, k):
    return jax.lax.conv_general_dilated(
        x, k, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _group_norm(x, scale, bias, groups: int = 8, eps: float = 1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) / jnp.sqrt(var + eps)
    return xg.reshape(n, h, w, c) * scale + bias


def _pool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def make_cnn(
    name: str,
    *,
    image: tuple[int, int, int] = (16, 16, 3),
    num_classes: int = 10,
    widths: tuple[int, int, int] = (16, 32, 64),
    batch: int = 16,
) -> FlatModel:
    h_img, w_img, c_in = image
    c0, c1, c2 = widths
    specs = [
        ParamSpec("conv0", (3, 3, c_in, c0)),
        ParamSpec("gn0_s", (c0,), "ones"),
        ParamSpec("gn0_b", (c0,), "zeros"),
        ParamSpec("conv1", (3, 3, c0, c1)),
        ParamSpec("gn1_s", (c1,), "ones"),
        ParamSpec("gn1_b", (c1,), "zeros"),
        # residual block 1 (width c1)
        ParamSpec("res1a", (3, 3, c1, c1)),
        ParamSpec("gn2_s", (c1,), "ones"),
        ParamSpec("gn2_b", (c1,), "zeros"),
        ParamSpec("res1b", (3, 3, c1, c1)),
        ParamSpec("gn3_s", (c1,), "ones"),
        ParamSpec("gn3_b", (c1,), "zeros"),
        ParamSpec("conv2", (3, 3, c1, c2)),
        ParamSpec("gn4_s", (c2,), "ones"),
        ParamSpec("gn4_b", (c2,), "zeros"),
        # residual block 2 (width c2)
        ParamSpec("res2a", (3, 3, c2, c2)),
        ParamSpec("gn5_s", (c2,), "ones"),
        ParamSpec("gn5_b", (c2,), "zeros"),
        ParamSpec("res2b", (3, 3, c2, c2)),
        ParamSpec("gn6_s", (c2,), "ones"),
        ParamSpec("gn6_b", (c2,), "zeros"),
        ParamSpec("head_w", (c2, num_classes)),
        ParamSpec("head_b", (num_classes,), "zeros"),
    ]

    def forward(p, x):
        h = jnp.maximum(_group_norm(_conv(x, p["conv0"]), p["gn0_s"], p["gn0_b"]), 0.0)
        h = jnp.maximum(_group_norm(_conv(h, p["conv1"]), p["gn1_s"], p["gn1_b"]), 0.0)
        h = _pool2(h)
        r = jnp.maximum(_group_norm(_conv(h, p["res1a"]), p["gn2_s"], p["gn2_b"]), 0.0)
        r = jnp.maximum(_group_norm(_conv(r, p["res1b"]), p["gn3_s"], p["gn3_b"]), 0.0)
        h = h + r
        h = jnp.maximum(_group_norm(_conv(h, p["conv2"]), p["gn4_s"], p["gn4_b"]), 0.0)
        h = _pool2(h)
        r = jnp.maximum(_group_norm(_conv(h, p["res2a"]), p["gn5_s"], p["gn5_b"]), 0.0)
        r = jnp.maximum(_group_norm(_conv(r, p["res2b"]), p["gn6_s"], p["gn6_b"]), 0.0)
        h = h + r
        h = jnp.mean(h, axis=(1, 2))  # global average pool
        return h @ p["head_w"] + p["head_b"]

    def loss(p, x, y, mask):
        sum_ce, units, _ = masked_ce_from_logits(forward(p, x), y, mask)
        return mean_masked_loss(sum_ce, units)

    def stats(p, x, y, mask):
        return masked_ce_from_logits(forward(p, x), y, mask)

    return FlatModel(
        name=name,
        specs=specs,
        _loss=loss,
        _stats=stats,
        input_spec={
            "x": ((batch, h_img, w_img, c_in), "f32"),
            "y": ((batch,), "i32"),
            "mask": ((batch,), "f32"),
        },
    )
