"""MLP image classifier (flat-weight convention).

Used for the FEMNIST-analog task and the smoke-test task: small, fast to
differentiate on CPU, and still exhibits the heavy-hitter gradient
structure FetchSGD exploits (per-class output rows dominate under label
skew).
"""

from __future__ import annotations

import jax.numpy as jnp

from .common import FlatModel, ParamSpec, masked_ce_from_logits, mean_masked_loss


def make_mlp(
    name: str,
    *,
    input_shape: tuple[int, ...],
    num_classes: int,
    hidden: tuple[int, ...] = (256, 128),
    batch: int = 16,
) -> FlatModel:
    in_dim = 1
    for s in input_shape:
        in_dim *= s
    dims = [in_dim, *hidden, num_classes]
    specs: list[ParamSpec] = []
    for li in range(len(dims) - 1):
        specs.append(ParamSpec(f"w{li}", (dims[li], dims[li + 1]), "dense"))
        specs.append(ParamSpec(f"b{li}", (dims[li + 1],), "zeros"))

    n_layers = len(dims) - 1

    def forward(params, x):
        hcur = x.reshape(x.shape[0], -1)
        for li in range(n_layers):
            hcur = hcur @ params[f"w{li}"] + params[f"b{li}"]
            if li < n_layers - 1:
                hcur = jnp.maximum(hcur, 0.0)
        return hcur

    def loss(params, x, y, mask):
        sum_ce, units, _ = masked_ce_from_logits(forward(params, x), y, mask)
        return mean_masked_loss(sum_ce, units)

    def stats(params, x, y, mask):
        return masked_ce_from_logits(forward(params, x), y, mask)

    return FlatModel(
        name=name,
        specs=specs,
        _loss=loss,
        _stats=stats,
        input_spec={
            "x": ((batch, *input_shape), "f32"),
            "y": ((batch,), "i32"),
            "mask": ((batch,), "f32"),
        },
    )
