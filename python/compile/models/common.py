"""Flat-weight-vector model convention shared by all L2 models.

The Rust coordinator owns the model state as a single ``f32[d]`` vector
(that is what FetchSGD sketches, updates sparsely, and broadcasts), so
every model exposes:

- ``specs``: the ordered list of named parameter shapes;
- ``init_flat(seed)``: deterministic initial weights as one numpy vector;
- ``loss(w_flat, x, y, mask)``: scalar masked mean loss, differentiable
  wrt ``w_flat`` (gradients therefore come out flat, ready to sketch);
- ``eval_stats(w_flat, x, y, mask)``: (sum_loss, units, correct) for
  accuracy/perplexity aggregation across eval batches.

Packing/unpacking uses static offsets, so XLA sees plain slices.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    # "dense" → scaled-normal fan-in init; "zeros"; "ones"; "embed" →
    # N(0, 0.02) like GPT-2.
    init: str = "dense"

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclasses.dataclass
class FlatModel:
    """A model over a flat parameter vector."""

    name: str
    specs: list[ParamSpec]
    # loss(params_dict, x, y, mask) -> scalar
    _loss: Callable
    # stats(params_dict, x, y, mask) -> (sum_loss, units, correct)
    _stats: Callable
    # batch input shapes/dtypes, e.g. {"x": ((B,16,16,3),"f32"), ...}
    input_spec: dict

    @property
    def dim(self) -> int:
        return sum(s.size for s in self.specs)

    def offsets(self) -> list[tuple[ParamSpec, int]]:
        out, off = [], 0
        for s in self.specs:
            out.append((s, off))
            off += s.size
        return out

    def unpack(self, w_flat: jnp.ndarray) -> dict[str, jnp.ndarray]:
        params = {}
        for s, off in self.offsets():
            params[s.name] = w_flat[off : off + s.size].reshape(s.shape)
        return params

    def init_flat(self, seed: int) -> np.ndarray:
        rng = np.random.default_rng(seed)
        parts = []
        for s in self.specs:
            if s.init == "zeros":
                parts.append(np.zeros(s.size, np.float32))
            elif s.init == "ones":
                parts.append(np.ones(s.size, np.float32))
            elif s.init == "embed":
                parts.append(rng.normal(0.0, 0.02, s.size).astype(np.float32))
            else:  # dense: He-style fan-in scaling
                fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.size, 1)
                if len(s.shape) == 4:  # conv HWIO: fan_in = H*W*I
                    fan_in = s.shape[0] * s.shape[1] * s.shape[2]
                std = float(np.sqrt(2.0 / fan_in))
                parts.append(rng.normal(0.0, std, s.size).astype(np.float32))
        return np.concatenate(parts)

    def loss(self, w_flat, x, y, mask):
        return self._loss(self.unpack(w_flat), x, y, mask)

    def eval_stats(self, w_flat, x, y, mask):
        return self._stats(self.unpack(w_flat), x, y, mask)


def masked_ce_from_logits(logits: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray):
    """(sum_ce, units, correct) for logits (..., V), labels (...), mask (...)."""
    logp = jnp.take_along_axis(
        _log_softmax(logits), y[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    ce = -logp
    sum_ce = jnp.sum(ce * mask)
    units = jnp.sum(mask)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == y).astype(jnp.float32) * mask)
    return sum_ce, units, correct


def _log_softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    s = x - m
    return s - jnp.log(jnp.sum(jnp.exp(s), axis=-1, keepdims=True))


def mean_masked_loss(sum_ce, units):
    return sum_ce / jnp.maximum(units, 1.0)
