"""Decoder-only transformer LM — the GPT2-small analog.

Character-level causal language model with pre-LN blocks, learned
positional embeddings, and tied input/output embeddings (keeps the
parameter count honest at small scale). The PersonaChat-analog task
finetunes/trains this on a persona-conditioned synthetic corpus; the
metric is token perplexity, as in the paper.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import FlatModel, ParamSpec, masked_ce_from_logits, mean_masked_loss


def _layer_norm(x, scale, bias, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def make_transformer(
    name: str,
    *,
    vocab: int = 64,
    seq: int = 32,
    dim: int = 64,
    heads: int = 4,
    layers: int = 2,
    mlp_mult: int = 4,
    batch: int = 8,
) -> FlatModel:
    assert dim % heads == 0
    head_dim = dim // heads
    specs = [
        ParamSpec("embed", (vocab, dim), "embed"),
        ParamSpec("pos", (seq, dim), "embed"),
    ]
    for li in range(layers):
        specs += [
            ParamSpec(f"l{li}_ln1_s", (dim,), "ones"),
            ParamSpec(f"l{li}_ln1_b", (dim,), "zeros"),
            ParamSpec(f"l{li}_qkv", (dim, 3 * dim)),
            ParamSpec(f"l{li}_proj", (dim, dim)),
            ParamSpec(f"l{li}_ln2_s", (dim,), "ones"),
            ParamSpec(f"l{li}_ln2_b", (dim,), "zeros"),
            ParamSpec(f"l{li}_fc1", (dim, mlp_mult * dim)),
            ParamSpec(f"l{li}_fc1b", (mlp_mult * dim,), "zeros"),
            ParamSpec(f"l{li}_fc2", (mlp_mult * dim, dim)),
            ParamSpec(f"l{li}_fc2b", (dim,), "zeros"),
        ]
    specs += [ParamSpec("lnf_s", (dim,), "ones"), ParamSpec("lnf_b", (dim,), "zeros")]

    causal = np.tril(np.ones((seq, seq), np.float32))
    neg_inf = -1e9

    def forward(p, x):
        # x: (B, S) int32 tokens -> logits (B, S, V)
        h = p["embed"][x] + p["pos"][None, :, :]
        b = x.shape[0]
        for li in range(layers):
            hn = _layer_norm(h, p[f"l{li}_ln1_s"], p[f"l{li}_ln1_b"])
            qkv = hn @ p[f"l{li}_qkv"]  # (B,S,3D)
            q, k, v = jnp.split(qkv, 3, axis=-1)
            q = q.reshape(b, seq, heads, head_dim).transpose(0, 2, 1, 3)
            k = k.reshape(b, seq, heads, head_dim).transpose(0, 2, 1, 3)
            v = v.reshape(b, seq, heads, head_dim).transpose(0, 2, 1, 3)
            att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(head_dim)
            att = jnp.where(causal[None, None, :, :] > 0, att, neg_inf)
            att = att - jnp.max(att, axis=-1, keepdims=True)
            att = jnp.exp(att)
            att = att / jnp.sum(att, axis=-1, keepdims=True)
            out = (att @ v).transpose(0, 2, 1, 3).reshape(b, seq, dim)
            h = h + out @ p[f"l{li}_proj"]
            hn = _layer_norm(h, p[f"l{li}_ln2_s"], p[f"l{li}_ln2_b"])
            ff = jnp.maximum(hn @ p[f"l{li}_fc1"] + p[f"l{li}_fc1b"], 0.0)
            h = h + ff @ p[f"l{li}_fc2"] + p[f"l{li}_fc2b"]
        h = _layer_norm(h, p["lnf_s"], p["lnf_b"])
        return h @ p["embed"].T  # tied output head

    def loss(p, x, y, mask):
        sum_ce, units, _ = masked_ce_from_logits(forward(p, x), y, mask)
        return mean_masked_loss(sum_ce, units)

    def stats(p, x, y, mask):
        return masked_ce_from_logits(forward(p, x), y, mask)

    return FlatModel(
        name=name,
        specs=specs,
        _loss=loss,
        _stats=stats,
        input_spec={
            "x": ((batch, seq), "i32"),
            "y": ((batch, seq), "i32"),
            "mask": ((batch, seq), "f32"),
        },
    )
