"""L2 glue: the jitted client/server computations lowered to artifacts.

Each function below closes over a `FlatModel` (and, for the FetchSGD
path, the L1 sketch kernel) and is AOT-lowered by ``aot.py`` to one HLO
module per (task, kind):

- ``client_step``  — FetchSGD client: (w, x, y, mask) -> (loss, S(grad)).
  The gradient never leaves the device densely; the Pallas Count-Sketch
  kernel compresses it *inside this graph*.
- ``client_grad``  — baseline client: (w, x, y, mask) -> (loss, grad).
  Used by uncompressed SGD, local top-k (top-k selection happens in the
  Rust client — it is O(d) selection, not model compute), and true top-k.
- ``fedavg_step``  — FedAvg client: K local SGD steps over pre-batched
  local data; returns (mean_loss, delta) with delta = w_in − w_out.
- ``eval_step``    — forward-only: (w, x, y, mask) -> (sum_loss, units,
  correct) for test accuracy / perplexity aggregation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import SketchHasher, sketch_encode
from .models.common import FlatModel


def make_client_step(model: FlatModel, hasher: SketchHasher, *, strategy: str = "scatter",
                     block: int = 2048):
    """FetchSGD client computation: loss + sketched gradient."""

    def client_step(w, x, y, mask):
        loss, grad = jax.value_and_grad(model.loss)(w, x, y, mask)
        table = sketch_encode(grad, h=hasher, strategy=strategy, block=block)
        return loss, table

    return client_step


def make_client_grad(model: FlatModel):
    """Baseline client computation: loss + dense gradient."""

    def client_grad(w, x, y, mask):
        loss, grad = jax.value_and_grad(model.loss)(w, x, y, mask)
        return loss, grad

    return client_grad


def make_fedavg_step(model: FlatModel, local_steps: int):
    """FedAvg client: `local_steps` sequential SGD steps on local batches.

    Inputs are pre-batched on the Rust side: xs/(ys/masks) carry a
    leading `local_steps` axis. `lr` is a scalar so the server's learning
    rate schedule applies without re-lowering.
    """

    def fedavg_step(w, xs, ys, masks, lr):
        def step(w_cur, batch):
            x, y, m = batch
            loss, grad = jax.value_and_grad(model.loss)(w_cur, x, y, m)
            return w_cur - lr * grad, loss

        w_out, losses = jax.lax.scan(step, w, (xs, ys, masks))
        return jnp.mean(losses), w - w_out

    return fedavg_step


def make_eval_step(model: FlatModel):
    """Forward-only evaluation statistics."""

    def eval_step(w, x, y, mask):
        sum_ce, units, correct = model.eval_stats(w, x, y, mask)
        return sum_ce, units, correct

    return eval_step
