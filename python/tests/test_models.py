"""L2 model tests: shapes, gradients, masking semantics, and the client
computations that get lowered to artifacts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import SketchHasher
from compile.kernels.ref import sketch_encode_ref
from compile.model import make_client_grad, make_client_step, make_eval_step, make_fedavg_step
from compile.models import make_cnn, make_mlp, make_transformer


def _models():
    return [
        make_mlp("mlp", input_shape=(8, 8, 1), num_classes=10, hidden=(32,), batch=4),
        make_cnn("cnn", image=(8, 8, 3), num_classes=10, widths=(4, 8, 8), batch=4),
        make_transformer("tfm", vocab=32, seq=16, dim=32, heads=2, layers=1, batch=2),
    ]


def _batch(model, seed=0):
    rng = np.random.default_rng(seed)
    (xs, xd) = model.input_spec["x"]
    (ys, _) = model.input_spec["y"]
    (ms, _) = model.input_spec["mask"]
    if xd == "f32":
        x = rng.normal(size=xs).astype(np.float32)
        y = rng.integers(0, 10, size=ys).astype(np.int32)
    else:
        x = rng.integers(0, 32, size=xs).astype(np.int32)
        y = rng.integers(0, 32, size=ys).astype(np.int32)
    mask = np.ones(ms, np.float32)
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


@pytest.mark.parametrize("model", _models(), ids=lambda m: m.name)
def test_init_deterministic_and_sized(model):
    w1 = model.init_flat(7)
    w2 = model.init_flat(7)
    w3 = model.init_flat(8)
    assert w1.shape == (model.dim,)
    np.testing.assert_array_equal(w1, w2)
    assert not np.array_equal(w1, w3)
    assert np.isfinite(w1).all()


@pytest.mark.parametrize("model", _models(), ids=lambda m: m.name)
def test_loss_finite_and_grad_nonzero(model):
    w = jnp.asarray(model.init_flat(1))
    x, y, mask = _batch(model)
    loss, grad = jax.value_and_grad(model.loss)(w, x, y, mask)
    assert np.isfinite(float(loss))
    assert grad.shape == (model.dim,)
    assert float(jnp.abs(grad).max()) > 0.0
    assert np.isfinite(np.asarray(grad)).all()


@pytest.mark.parametrize("model", _models(), ids=lambda m: m.name)
def test_mask_zero_examples_dont_contribute(model):
    w = jnp.asarray(model.init_flat(1))
    x, y, mask = _batch(model)
    # zero out the last example; perturbing its data must not change loss
    mask0 = np.asarray(mask).copy()
    if mask0.ndim == 1:
        mask0[-1] = 0.0
    else:
        mask0[-1, :] = 0.0
    mask0 = jnp.asarray(mask0)
    loss1 = model.loss(w, x, y, mask0)
    x2 = np.asarray(x).copy()
    x2[-1] = x2[0]  # clobber masked example
    loss2 = model.loss(w, jnp.asarray(x2), y, mask0)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)


def test_gradient_descent_reduces_loss():
    model = make_mlp("m", input_shape=(8, 8, 1), num_classes=4, hidden=(16,), batch=8)
    w = jnp.asarray(model.init_flat(3))
    x, y, mask = _batch(model)
    y = jnp.asarray(np.arange(8, dtype=np.int32) % 4)
    l0 = float(model.loss(w, x, y, mask))
    for _ in range(30):
        g = jax.grad(model.loss)(w, x, y, mask)
        w = w - 0.5 * g
    l1 = float(model.loss(w, x, y, mask))
    assert l1 < l0 * 0.5, f"{l0} -> {l1}"


def test_client_step_sketch_matches_grad_sketch():
    """The fused (grad+sketch) computation must equal sketching the
    output of the grad computation — the invariant the Rust selfcheck
    verifies through the artifacts."""
    model = make_mlp("m", input_shape=(8, 8, 1), num_classes=10, hidden=(32,), batch=4)
    h = SketchHasher.create(5, 512, 42)
    step = make_client_step(model, h, block=512)
    grad_fn = make_client_grad(model)
    w = jnp.asarray(model.init_flat(1))
    x, y, mask = _batch(model)
    loss1, table = step(w, x, y, mask)
    loss2, grad = grad_fn(w, x, y, mask)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
    ref = sketch_encode_ref(h, grad)
    np.testing.assert_allclose(np.asarray(table), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_fedavg_step_applies_k_local_steps():
    model = make_mlp("m", input_shape=(8, 8, 1), num_classes=4, hidden=(16,), batch=4)
    k = 3
    fed = make_fedavg_step(model, k)
    w = jnp.asarray(model.init_flat(5))
    xs, ys, masks = [], [], []
    for j in range(k):
        x, y, m = _batch(model, seed=j)
        xs.append(x)
        ys.append(np.asarray(y) % 4)
        masks.append(m)
    xs = jnp.stack(xs)
    ys = jnp.asarray(np.stack(ys))
    masks = jnp.stack(masks)
    loss, delta = fed(w, xs, ys, masks, jnp.float32(0.1))
    # manual reference
    w_ref = w
    for j in range(k):
        g = jax.grad(model.loss)(w_ref, xs[j], ys[j], masks[j])
        w_ref = w_ref - 0.1 * g
    np.testing.assert_allclose(np.asarray(delta), np.asarray(w - w_ref), rtol=1e-4, atol=1e-6)
    assert np.isfinite(float(loss))
    # lr=0 -> zero delta
    _, d0 = fed(w, xs, ys, masks, jnp.float32(0.0))
    assert float(jnp.abs(d0).max()) == 0.0


def test_eval_step_counts():
    model = make_mlp("m", input_shape=(8, 8, 1), num_classes=4, hidden=(16,), batch=8)
    ev = make_eval_step(model)
    w = jnp.asarray(model.init_flat(2))
    x, y, mask = _batch(model)
    y = jnp.asarray(np.asarray(y) % 4)
    sum_ce, units, correct = ev(w, x, y, mask)
    assert float(units) == 8.0
    assert 0.0 <= float(correct) <= 8.0
    assert float(sum_ce) > 0.0
    # half mask -> half units
    m2 = np.ones(8, np.float32)
    m2[4:] = 0.0
    _, units2, correct2 = ev(w, x, y, jnp.asarray(m2))
    assert float(units2) == 4.0
    assert float(correct2) <= 4.0


def test_transformer_causality():
    """Changing a future token must not affect earlier positions'
    logits (causal masking)."""
    model = make_transformer("t", vocab=16, seq=8, dim=16, heads=2, layers=1, batch=1)
    w = jnp.asarray(model.init_flat(1))
    params = model.unpack(w)
    # direct forward access via loss machinery: compare per-position CE
    x1 = np.zeros((1, 8), np.int32)
    x2 = x1.copy()
    x2[0, -1] = 5  # change only the last input token
    y = np.zeros((1, 8), np.int32)
    # mask only position 0: loss depends solely on position 0's logits
    m = np.zeros((1, 8), np.float32)
    m[0, 0] = 1.0
    l1 = float(model.loss(w, jnp.asarray(x1), jnp.asarray(y), jnp.asarray(m)))
    l2 = float(model.loss(w, jnp.asarray(x2), jnp.asarray(y), jnp.asarray(m)))
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    del params


def test_param_spec_offsets_cover_dim():
    for model in _models():
        total = sum(s.size for s in model.specs)
        assert total == model.dim
        offs = model.offsets()
        assert offs[0][1] == 0
        for (s1, o1), (_, o2) in zip(offs, offs[1:]):
            assert o2 == o1 + s1.size
