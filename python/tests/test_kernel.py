"""Pallas kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes, seeds, sketch geometries, strategies, and
dtypes; every case must match ref.py to float tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import SketchHasher, sketch_encode, unsketch_estimate
from compile.kernels.ref import sketch_encode_ref, top_k_ref, unsketch_estimate_ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return rng.normal(size=d).astype(dtype)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d=st.integers(min_value=1, max_value=5000),
    log_cols=st.integers(min_value=3, max_value=12),
    rows=st.sampled_from([1, 3, 5]),
    seed=st.integers(min_value=0, max_value=2**63),
    strategy=st.sampled_from(["scatter", "onehot"]),
)
def test_encode_matches_ref(d, log_cols, rows, seed, strategy):
    h = SketchHasher.create(rows, 1 << log_cols, seed)
    g = jnp.asarray(_rand(d, seed % 1000))
    ref = sketch_encode_ref(h, g)
    out = sketch_encode(g, h=h, strategy=strategy, block=512, col_tile=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    d=st.integers(min_value=1, max_value=3000),
    block=st.sampled_from([64, 256, 1024, 4096]),
)
def test_encode_block_size_invariant(d, block):
    """Blocking is an implementation detail: results identical across
    block sizes (including d not divisible by block)."""
    h = SketchHasher.create(3, 256, 11)
    g = jnp.asarray(_rand(d, 5))
    a = sketch_encode(g, h=h, block=block)
    b = sketch_encode(g, h=h, block=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_encode_linearity():
    h = SketchHasher.create(5, 512, 3)
    a = jnp.asarray(_rand(2000, 1))
    b = jnp.asarray(_rand(2000, 2))
    sa = sketch_encode(a, h=h)
    sb = sketch_encode(b, h=h)
    sab = sketch_encode(a + b, h=h)
    np.testing.assert_allclose(np.asarray(sa + sb), np.asarray(sab), rtol=1e-4, atol=1e-5)


def test_encode_bfloat16_input():
    h = SketchHasher.create(3, 256, 9)
    g32 = _rand(1000, 3)
    g16 = jnp.asarray(g32, dtype=jnp.bfloat16)
    out = sketch_encode(g16.astype(jnp.float32), h=h)
    ref = sketch_encode_ref(h, jnp.asarray(g32))
    # bf16 quantization noise: loose tolerance
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=0.1, atol=0.15)


def test_encode_zero_vector():
    h = SketchHasher.create(3, 64, 1)
    out = sketch_encode(jnp.zeros(100), h=h)
    assert np.all(np.asarray(out) == 0.0)


# ---------------------------------------------------------------------------
# unsketch / estimate
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d=st.integers(min_value=1, max_value=4000),
    rows=st.sampled_from([1, 3, 5]),
    seed=st.integers(min_value=0, max_value=2**31),
    strategy=st.sampled_from(["gather", "onehot"]),
)
def test_estimate_matches_ref(d, rows, seed, strategy):
    h = SketchHasher.create(rows, 512, seed)
    g = jnp.asarray(_rand(d, seed % 997))
    table = sketch_encode_ref(h, g)
    ref = unsketch_estimate_ref(h, table, d)
    out = unsketch_estimate(table, h=h, d=d, strategy=strategy, block=512, col_tile=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_roundtrip_recovers_heavy_hitters():
    """Sketch then unsketch: planted heavy coordinates must be the top-k
    of the estimates (the property FetchSGD's Δ extraction relies on)."""
    d = 20_000
    rng = np.random.default_rng(0)
    g = rng.normal(scale=0.01, size=d).astype(np.float32)
    planted = [17, 4242, 9999, 15000]
    for i, p in enumerate(planted):
        g[p] = 5.0 * (i + 1)
    h = SketchHasher.create(5, 2048, 77)
    table = sketch_encode(jnp.asarray(g), h=h)
    est = unsketch_estimate(table, h=h, d=d)
    idx, vals = top_k_ref(est, 4)
    assert set(np.asarray(idx).tolist()) == set(planted)
    for i, v in zip(np.asarray(idx), np.asarray(vals)):
        np.testing.assert_allclose(v, g[int(i)], rtol=0.05)


def test_estimate_unbiased_over_seeds():
    """U(S(g))_i is an unbiased estimate of g_i: average over many hash
    seeds converges to the true value."""
    d = 512
    g = np.zeros(d, np.float32)
    g[7] = 1.0
    g[100] = -2.0
    target = 300
    ests = []
    for seed in range(40):
        h = SketchHasher.create(1, 64, seed)  # tiny sketch, heavy collisions
        table = sketch_encode_ref(h, jnp.asarray(g))
        est = unsketch_estimate_ref(h, table, d)
        ests.append(np.asarray(est)[target])
    assert abs(np.mean(ests)) < 0.3, "collision noise should average to zero"


# ---------------------------------------------------------------------------
# shapes / errors
# ---------------------------------------------------------------------------


def test_encode_rejects_non_flat():
    h = SketchHasher.create(3, 64, 1)
    with pytest.raises(AssertionError):
        sketch_encode(jnp.zeros((4, 4)), h=h)


def test_unknown_strategy_raises():
    h = SketchHasher.create(3, 64, 1)
    with pytest.raises(ValueError):
        sketch_encode(jnp.zeros(16), h=h, strategy="bogus")
    with pytest.raises(ValueError):
        unsketch_estimate(jnp.zeros((3, 64)), h=h, d=16, strategy="bogus")


def test_encode_jit_cache_reuse():
    """Repeated calls with the same static config must not retrace (guards
    against accidentally unhashable statics)."""
    h = SketchHasher.create(3, 256, 5)
    g = jnp.asarray(_rand(1000, 1))
    a = sketch_encode(g, h=h)
    b = sketch_encode(g + 1.0, h=h)
    assert a.shape == b.shape == (3, 256)
