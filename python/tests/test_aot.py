"""AOT pipeline tests: HLO text lowering round-trips and the weights-file
header matches the Rust reader."""

import pathlib
import struct
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import SketchHasher
from compile.model import make_client_step
from compile.models import make_mlp


def test_to_hlo_text_produces_parseable_module():
    model = make_mlp("m", input_shape=(4, 4, 1), num_classes=4, hidden=(8,), batch=2)
    h = SketchHasher.create(3, 64, 5)
    step = make_client_step(model, h, block=64)
    w = jax.ShapeDtypeStruct((model.dim,), np.float32)
    x = jax.ShapeDtypeStruct((2, 4, 4, 1), np.float32)
    y = jax.ShapeDtypeStruct((2,), np.int32)
    m = jax.ShapeDtypeStruct((2,), np.float32)
    lowered = jax.jit(step).lower(w, x, y, m)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # must not contain Mosaic custom-calls (interpret=True requirement)
    assert "tpu_custom_call" not in text


def test_weights_bin_header():
    w = np.arange(10, dtype=np.float32)
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "w.bin"
        aot.write_weights_bin(p, w)
        raw = p.read_bytes()
        assert raw[:8] == b"FSGDF32\0"
        (n,) = struct.unpack("<Q", raw[8:16])
        assert n == 10
        back = np.frombuffer(raw[16:], dtype="<f4")
        np.testing.assert_array_equal(back, w)


def test_task_table_is_consistent():
    tasks = aot._tasks()
    assert "smoke" in tasks and "cifar10" in tasks and "persona" in tasks
    for name, cfg in tasks.items():
        model = cfg["model"]()
        assert model.dim > 0, name
        for cols in cfg["sketch_cols"]:
            assert cols & (cols - 1) == 0, f"{name}: cols {cols} not a power of 2"
        assert cfg["fedavg_steps"], name
        assert cfg["data"]["kind"] in ("images", "text")


def test_smoke_manifest_matches_model(tmp_path):
    # lower just the smoke task into a temp dir and check the manifest
    import json

    manifest = {"spec_version": 1, "sketch_rows": aot.SKETCH_ROWS, "tasks": []}
    aot.lower_task("smoke", aot._tasks()["smoke"], tmp_path, manifest)
    entry = manifest["tasks"][0]
    model = aot._tasks()["smoke"]["model"]()
    assert entry["dim"] == model.dim
    assert (tmp_path / entry["init_weights"]).exists()
    for kind, fname in entry["artifacts"].items():
        text = (tmp_path / fname).read_text()
        assert "HloModule" in text, kind
    json.dumps(manifest)  # serializable
