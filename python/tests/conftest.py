import pathlib
import sys

# Make `compile.*` importable when pytest runs from python/ or repo root.
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
