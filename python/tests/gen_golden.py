"""Generate golden cross-language hash vectors.

Run once (checked into the repo); both python/tests/test_hashing.py and
the Rust unit test `hashing::tests::golden_cross_language_vectors` assert
against this file, pinning the two implementations together.
"""

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np

from compile.kernels.hashing import SketchHasher

ROWS, COLS, SEED = 3, 1 << 12, 0xFE7C5D11
IDX = np.array([0, 1, 2, 1000, 65537, 4000000000], dtype=np.uint32)


def main() -> None:
    h = SketchHasher.create(ROWS, COLS, SEED)
    out = {
        "rows": ROWS,
        "cols": COLS,
        "seed": SEED,
        "idx": [int(i) for i in IDX],
        "buckets": [[int(b) for b in h.bucket_np(r, IDX)] for r in range(ROWS)],
        "signs": [[float(s) for s in h.sign_np(r, IDX)] for r in range(ROWS)],
    }
    path = pathlib.Path(__file__).parent / "golden_hash_vectors.json"
    path.write_text(json.dumps(out, indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
