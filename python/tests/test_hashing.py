"""Hash-spec tests: determinism, range, balance, and the golden vectors
shared with the Rust implementation (rust/src/hashing.rs)."""

import json
import pathlib

import numpy as np
import pytest

from compile.kernels.hashing import SketchHasher, splitmix64


def test_splitmix_deterministic():
    v1, s1 = splitmix64(1234567)
    v2, s2 = splitmix64(1234567)
    assert v1 == v2 and s1 == s2
    v3, _ = splitmix64(s1)
    assert v3 != v1


def test_bucket_range_and_uniformity():
    h = SketchHasher.create(1, 128, 7)
    idx = np.arange(128 * 200, dtype=np.uint32)
    b = h.bucket_np(0, idx)
    assert b.min() >= 0 and b.max() < 128
    counts = np.bincount(b, minlength=128)
    assert counts.min() > 50 and counts.max() < 400


def test_signs_balanced():
    h = SketchHasher.create(5, 64, 21)
    idx = np.arange(10_000, dtype=np.uint32)
    for r in range(5):
        s = h.sign_np(r, idx)
        assert set(np.unique(s)) <= {-1.0, 1.0}
        pos = (s > 0).sum()
        assert 4000 < pos < 6000


def test_jnp_matches_np():
    import jax.numpy as jnp

    h = SketchHasher.create(3, 1024, 99)
    idx = np.array([0, 1, 5, 1000, 2**31, 2**32 - 1], dtype=np.uint32)
    for r in range(3):
        np.testing.assert_array_equal(
            np.asarray(h.bucket_jnp(r, jnp.asarray(idx))), h.bucket_np(r, idx)
        )
        np.testing.assert_array_equal(
            np.asarray(h.sign_jnp(r, jnp.asarray(idx))), h.sign_np(r, idx)
        )


def test_golden_cross_language_vectors():
    """Pins the Python implementation to the committed golden file; the
    Rust test hashing::tests::golden_cross_language_vectors asserts the
    same values, tying the two implementations together."""
    path = pathlib.Path(__file__).parent / "golden_hash_vectors.json"
    g = json.loads(path.read_text())
    h = SketchHasher.create(g["rows"], g["cols"], g["seed"])
    idx = np.array(g["idx"], dtype=np.uint32)
    for r in range(g["rows"]):
        assert [int(x) for x in h.bucket_np(r, idx)] == g["buckets"][r]
        assert [float(x) for x in h.sign_np(r, idx)] == g["signs"][r]


def test_rejects_bad_cols():
    with pytest.raises(AssertionError):
        SketchHasher.create(3, 100, 1)
    with pytest.raises(AssertionError):
        SketchHasher.create(0, 64, 1)
