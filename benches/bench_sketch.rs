//! Sketch-operation microbenchmarks (L3 server hot path).
//!
//! The FetchSGD server per round: merge W client sketches, momentum and
//! error updates (sketch-space linear ops), estimate_all (U(S_e)),
//! top-k selection, zero-out. These benches size each piece; §Perf in
//! EXPERIMENTS.md records the befores/afters of the optimization pass.
//! Set `BENCH_JSON=<path>` to also emit machine-readable results (the
//! committed `BENCH_*.json` baselines).

use fetchsgd::bench_util::{bench, bench_throughput, print_table, write_json_suite};
use fetchsgd::sketch::{CountSketch, SparseVec};
use fetchsgd::util::Rng;

fn random_vec(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..d).map(|_| rng.next_gaussian() as f32).collect()
}

fn main() {
    let mut results = Vec::new();

    // encode: client-side fallback / test path (the production encode
    // runs inside the HLO artifact).
    for &d in &[100_000usize, 1_000_000] {
        let g = random_vec(d, 1);
        results.push(bench_throughput(
            &format!("encode dense d={d} (5x16384)"),
            2,
            8,
            d as u64,
            || CountSketch::encode(5, 16384, 7, &g).unwrap(),
        ));
    }

    // merge: W=100 sketch aggregation.
    {
        let sketches: Vec<CountSketch> = (0..100)
            .map(|i| CountSketch::encode(5, 16384, 7, &random_vec(10_000, i)).unwrap())
            .collect();
        results.push(bench_throughput("merge W=100 (5x16384)", 2, 10, 100 * 5 * 16384, || {
            let mut agg = CountSketch::zeros(5, 16384, 10_000, 7).unwrap();
            for s in &sketches {
                agg.add_scaled(s, 0.01);
            }
            agg
        }));
    }

    // estimate_all: the unsketch hot path U(S_e). The "generic" variant
    // is the pre-optimization implementation (per-coordinate median
    // sort, coordinate-major access) kept for §Perf before/after.
    for &d in &[100_000usize, 1_000_000] {
        let g = random_vec(d, 3);
        let s = CountSketch::encode(5, 16384, 7, &g).unwrap();
        let mut out = vec![0f32; d];
        results.push(bench_throughput(
            &format!("estimate_all d={d} GENERIC (before)"),
            2,
            8,
            d as u64,
            || s.estimate_all_into_generic(&mut out),
        ));
        results.push(bench_throughput(
            &format!("estimate_all d={d} (5x16384)"),
            2,
            8,
            d as u64,
            || s.estimate_all_into(&mut out),
        ));
    }

    // top-k selection over estimates.
    {
        let est = random_vec(1_000_000, 9);
        results.push(bench_throughput("top_k k=50000 of 1M", 2, 8, 1_000_000, || {
            fetchsgd::sketch::top_k_indices(&est, 50_000)
        }));
    }

    // zero-out of an extracted update.
    {
        let mut s = CountSketch::encode(5, 16384, 7, &random_vec(1_000_000, 5)).unwrap();
        let pairs: Vec<(u32, f32)> = (0..50_000u32).map(|i| (i * 17 % 1_000_000, 1.0)).collect();
        let mut dedup: Vec<(u32, f32)> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for (i, v) in pairs {
            if seen.insert(i) {
                dedup.push((i, v));
            }
        }
        let delta = SparseVec::from_pairs(1_000_000, dedup);
        results.push(bench("zero_out nnz=50000 (5x16384)", 2, 10, || {
            s.zero_out_sparse(&delta);
        }));
    }

    // encode hashing: the simd dispatch vs its always-compiled scalar
    // twin, measured side by side in the same run. With the `simd`
    // feature off both rows run the scalar code (they should read
    // equal); with it on the spread is what the SSE2 multiply-shift
    // hashing and blocked kernels buy. Bits are identical either way —
    // that is `prop_simd_dispatch_matches_scalar_twin_bitwise`'s job.
    {
        use fetchsgd::hashing::SketchHasher;
        use fetchsgd::util::simd::{self, scalar};
        let d = 1_000_000;
        let cols = 16384usize;
        let g = random_vec(d, 21);
        let hasher = SketchHasher::new(1, cols, 7).unwrap();
        let h = hasher.row(0);
        let shift = 32 - (cols as u32).trailing_zeros();
        let mut row = vec![0f32; cols];
        results.push(bench_throughput(
            &format!("encode hash+scatter d={d} DISPATCH (1x{cols})"),
            2,
            8,
            d as u64,
            || simd::accumulate_row(&mut row, h, shift, &g, 1.0),
        ));
        results.push(bench_throughput(
            &format!("encode hash+scatter d={d} SCALAR (1x{cols})"),
            2,
            8,
            d as u64,
            || scalar::accumulate_row(&mut row, h, shift, &g, 1.0),
        ));
        // The dense linear kernel under every sketch-space merge.
        let n = 5 * cols;
        let src = random_vec(n, 23);
        let mut dst = vec![0f32; n];
        results.push(bench_throughput(
            &format!("axpy {n} DISPATCH"),
            2,
            20,
            n as u64,
            || simd::axpy(&mut dst, &src, 0.01),
        ));
        results.push(bench_throughput(&format!("axpy {n} SCALAR"), 2, 20, n as u64, || {
            scalar::axpy(&mut dst, &src, 0.01)
        }));
    }

    // row-strip-parallel shard reduce: the round pipeline's fan-in of
    // MAX_SHARDS accumulators, sequential vs striped (one strip per
    // table row ⇒ up to `rows` workers). Bits are identical at any
    // width — this sizes the speedup the reduce_parallelism knob buys.
    {
        use fetchsgd::compression::aggregate::{reduce_shards_in_place, RoundAccum, MAX_SHARDS};
        use fetchsgd::compression::{ClientUpload, UploadSpec};
        let d = 100_000;
        let spec = UploadSpec::Sketch { rows: 5, cols: 16384, dim: d, seed: 7 };
        let mut shards: Vec<RoundAccum> = (0..MAX_SHARDS)
            .map(|i| {
                let mut a = RoundAccum::new(&spec).unwrap();
                a.absorb(
                    ClientUpload::Sketch(
                        CountSketch::encode(5, 16384, 7, &random_vec(d, 50 + i as u64)).unwrap(),
                    ),
                    1.0 / MAX_SHARDS as f32,
                )
                .unwrap();
                a
            })
            .collect();
        for strips in [1usize, 5] {
            results.push(bench(
                &format!("reduce {MAX_SHARDS} shards (5x16384) strip-par={strips}"),
                2,
                20,
                || {
                    // Re-zero the destination so every iteration folds
                    // the same work.
                    shards[0].reset();
                    reduce_shards_in_place(&mut shards, strips).unwrap();
                },
            ));
        }
    }

    // full server round (merge + momentum + error + topk + zero-out),
    // d=100k, W=20 — the end-to-end L3 cost per round.
    {
        let d = 100_000;
        let uploads: Vec<CountSketch> =
            (0..20).map(|i| CountSketch::encode(5, 16384, 7, &random_vec(d, 100 + i)).unwrap()).collect();
        let mut momentum = CountSketch::zeros(5, 16384, d, 7).unwrap();
        let mut error = CountSketch::zeros(5, 16384, d, 7).unwrap();
        results.push(bench("server round d=100k W=20 k=1000", 1, 8, || {
            let mut round = CountSketch::zeros(5, 16384, d, 7).unwrap();
            for s in &uploads {
                round.add_scaled(s, 0.05);
            }
            momentum.scale(0.9);
            momentum.add_scaled(&round, 1.0);
            error.add_scaled(&momentum, 0.1);
            let delta = error.top_k(1000);
            error.zero_out_sparse(&delta);
            momentum.zero_out_sparse(&delta);
            delta
        }));
    }

    print_table("sketch ops", &results);
    write_json_suite("sketch", &results);
}
