//! Strategy server-step cost comparison (no PJRT needed): how expensive
//! is each method's aggregation + model update per round, at matched
//! geometry (d=100k, W=10)? Each bench runs the real server pipeline —
//! begin_round → incremental absorb → finish — exactly as the round
//! engine drives it. FetchSGD's server does strictly more work than the
//! baselines (unsketch + top-k); this bench quantifies the overhead
//! that the communication savings buy. Set `BENCH_JSON=<path>` to also
//! emit machine-readable results (the committed `BENCH_*.json`
//! baselines).

use fetchsgd::bench_util::{bench, print_table, write_json_suite};
use fetchsgd::compression::aggregate::run_server_round;
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::local_topk::LocalTopKServer;
use fetchsgd::compression::true_topk::TrueTopKServer;
use fetchsgd::compression::uncompressed::UncompressedServer;
use fetchsgd::compression::{ClientUpload, ServerAggregator};
use fetchsgd::sketch::topk::top_k_sparse;
use fetchsgd::sketch::CountSketch;
use fetchsgd::util::Rng;

const D: usize = 100_000;
const W: usize = 10;
const K: usize = 1000;
const COLS: usize = 16384;
const ROWS: usize = 5;
const SEED: u64 = 7;

fn random_grads() -> Vec<Vec<f32>> {
    (0..W)
        .map(|i| {
            let mut rng = Rng::new(i as u64);
            (0..D).map(|_| rng.next_gaussian() as f32).collect()
        })
        .collect()
}

/// Uniform-size shim over the library's `run_server_round`.
fn server_round(
    strat: &mut dyn ServerAggregator,
    uploads: Vec<ClientUpload>,
    w: &mut [f32],
    lr: f32,
) {
    let sizes = vec![1.0f32; uploads.len()];
    run_server_round(strat, &sizes, uploads, w, lr).unwrap();
}

fn main() {
    let grads = random_grads();
    let mut results = Vec::new();
    let mut w = vec![0f32; D];

    // FetchSGD server step (uploads pre-sketched, as in production).
    {
        let sketches: Vec<CountSketch> = grads
            .iter()
            .map(|g| CountSketch::encode(ROWS, COLS, SEED, g).unwrap())
            .collect();
        let mut strat = FetchSgdServer::new(
            ROWS, COLS, SEED, D, K, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        results.push(bench("fetchsgd server (5x16384, k=1000)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                sketches.iter().map(|s| ClientUpload::Sketch(s.clone())).collect();
            server_round(&mut strat, uploads, &mut w, 0.01)
        }));
    }

    // Local top-k server step.
    {
        let sparse: Vec<_> = grads.iter().map(|g| top_k_sparse(g, K)).collect();
        let mut strat = LocalTopKServer::new(D, 0.9, true);
        results.push(bench("local_topk server (k=1000)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                sparse.iter().map(|s| ClientUpload::Sparse(s.clone())).collect();
            server_round(&mut strat, uploads, &mut w, 0.01)
        }));
    }

    // True top-k server step (dense error feedback).
    {
        let mut strat = TrueTopKServer::new(D, K, 0.9, true);
        results.push(bench("true_topk server (dense e+u)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                grads.iter().map(|g| ClientUpload::Dense(g.clone())).collect();
            server_round(&mut strat, uploads, &mut w, 0.01)
        }));
    }

    // Uncompressed server step.
    {
        let mut strat = UncompressedServer::new(D, 0.9);
        results.push(bench("uncompressed server", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                grads.iter().map(|g| ClientUpload::Dense(g.clone())).collect();
            server_round(&mut strat, uploads, &mut w, 0.01)
        }));
    }

    // Client-side top-k selection (local_topk's extra client cost).
    results.push(bench("client top_k selection (d=100k)", 1, 8, || {
        top_k_sparse(&grads[0], K)
    }));

    print_table("strategy server-step cost (d=100k, W=10)", &results);
    write_json_suite("compression", &results);
}
