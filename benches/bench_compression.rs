//! Strategy server-step cost comparison (no PJRT needed): how expensive
//! is each method's aggregation + model update per round, at matched
//! geometry (d=100k, W=10)? FetchSGD's server does strictly more work
//! than the baselines (unsketch + top-k); this bench quantifies the
//! overhead that the communication savings buy.

use fetchsgd::bench_util::{bench, print_table};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgd};
use fetchsgd::compression::local_topk::LocalTopK;
use fetchsgd::compression::true_topk::TrueTopK;
use fetchsgd::compression::uncompressed::Uncompressed;
use fetchsgd::compression::{ClientUpload, Strategy};
use fetchsgd::sketch::topk::top_k_sparse;
use fetchsgd::sketch::CountSketch;
use fetchsgd::util::Rng;

const D: usize = 100_000;
const W: usize = 10;
const K: usize = 1000;
const COLS: usize = 16384;
const ROWS: usize = 5;
const SEED: u64 = 7;

fn random_grads() -> Vec<Vec<f32>> {
    (0..W)
        .map(|i| {
            let mut rng = Rng::new(i as u64);
            (0..D).map(|_| rng.next_gaussian() as f32).collect()
        })
        .collect()
}

fn main() {
    let grads = random_grads();
    let mut results = Vec::new();
    let mut w = vec![0f32; D];

    // FetchSGD server step (uploads pre-sketched, as in production).
    {
        let sketches: Vec<CountSketch> =
            grads.iter().map(|g| CountSketch::encode(ROWS, COLS, SEED, g)).collect();
        let mut strat =
            FetchSgd::new(ROWS, COLS, SEED, D, K, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
                .unwrap();
        results.push(bench("fetchsgd server (5x16384, k=1000)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                sketches.iter().map(|s| ClientUpload::Sketch(s.clone())).collect();
            strat.server_round(uploads, &mut w, 0.01).unwrap()
        }));
    }

    // Local top-k server step.
    {
        let sparse: Vec<_> = grads.iter().map(|g| top_k_sparse(g, K)).collect();
        let mut strat = LocalTopK::new(D, K, 0.9, true, false);
        results.push(bench("local_topk server (k=1000)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                sparse.iter().map(|s| ClientUpload::Sparse(s.clone())).collect();
            strat.server_round(uploads, &mut w, 0.01).unwrap()
        }));
    }

    // True top-k server step (dense error feedback).
    {
        let mut strat = TrueTopK::new(D, K, 0.9, true);
        results.push(bench("true_topk server (dense e+u)", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                grads.iter().map(|g| ClientUpload::Dense(g.clone())).collect();
            strat.server_round(uploads, &mut w, 0.01).unwrap()
        }));
    }

    // Uncompressed server step.
    {
        let mut strat = Uncompressed::new(D, 0.9);
        results.push(bench("uncompressed server", 1, 8, || {
            let uploads: Vec<ClientUpload> =
                grads.iter().map(|g| ClientUpload::Dense(g.clone())).collect();
            strat.server_round(uploads, &mut w, 0.01).unwrap()
        }));
    }

    // Client-side top-k selection (local_topk's extra client cost).
    results.push(bench("client top_k selection (d=100k)", 1, 8, || {
        top_k_sparse(&grads[0], K)
    }));

    print_table("strategy server-step cost (d=100k, W=10)", &results);
}
