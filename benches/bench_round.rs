//! End-to-end round latency and round-engine scaling.
//!
//! Four sections:
//!
//! 1. **Engine throughput (no artifacts needed)** — a 100-client
//!    FetchSGD cohort of simulated clients (synthetic gradient +
//!    client-side sketch encode, the same CPU shape as the real client
//!    step) driven through the parallel round engine at 1/2/4/N
//!    threads. Reports rounds/s and speedup vs single-thread; the
//!    shard-merge design keeps all of these bitwise identical.
//! 2. **Participation sweep (no artifacts needed)** — the same cohort
//!    with 0% / 20% / 50% of clients dropped at a 0.5 quorum, so the
//!    cost of membership bookkeeping and dropped-slot renormalization
//!    shows up in the perf trajectory.
//! 3. **Codec throughput (no artifacts needed)** — encode/decode GB/s
//!    per wire codec over a dense-payload-sized value buffer, bounding
//!    what wire mode costs on top of client compute.
//! 4. **Artifact round decomposition (requires `make artifacts`)** —
//!    client compute (PJRT execution of the fused grad+sketch HLO),
//!    server sketch update, and data generation, establishing where the
//!    bottleneck sits (the paper's contribution is the coordinator; it
//!    must not dominate).

use std::sync::Arc;

use fetchsgd::bench_util::{bench, print_table, BenchResult};
use fetchsgd::cohort::QuorumPolicy;
use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{sim_artifacts, SimDataset, SimFlakyClient, SimSketchClient};
use fetchsgd::compression::{ClientUpload, ServerAggregator};
use fetchsgd::coordinator::engine;
use fetchsgd::model::{build_dataset, DataScale};
use fetchsgd::runtime::artifact::{Manifest, TaskArtifacts};
use fetchsgd::runtime::exec::run_client_step;
use fetchsgd::runtime::Runtime;
use fetchsgd::sketch::CountSketch;
use fetchsgd::wire::{encode_upload, Codec, F16LE, F32LE};

/// One simulated FetchSGD round (client compute + sharded aggregation +
/// server finish) at a given worker count, optionally through the wire
/// encoding. Scratch accumulators are reused across iterations exactly
/// as the Trainer reuses them across rounds.
fn engine_round_bench(
    threads: usize,
    wire: Option<&'static dyn Codec>,
) -> anyhow::Result<BenchResult> {
    const DIM: usize = 200_000;
    const ROWS: usize = 5;
    const COLS: usize = 4096;
    const SEED: u64 = 7;
    const COHORT: usize = 100;

    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED)?;
    let dataset = SimDataset { num_clients: 10_000 };
    let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 8 };
    let mut server = FetchSgdServer::new(
        ROWS, COLS, SEED, DIM, 1000, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
    )?;
    let participants: Vec<usize> = (0..COHORT).collect();
    let mut w = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut round = 0u64;
    let tag = wire.map(|c| c.name()).unwrap_or("off");
    let policy = QuorumPolicy::strict();
    Ok(bench(&format!("engine round W=100 d=200k threads={threads} wire={tag}"), 1, 5, || {
        round += 1;
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: round,
            threads,
            wire,
            policy: &policy,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .expect("sim round");
        let update = server.finish(&out.merged, 0.1).expect("server finish");
        pipeline.recycle(out.merged);
        update.apply(&mut w);
        update
    }))
}

/// Encode/decode throughput per codec over a dense 4M-value payload
/// (16 MB of f32): GB/s of *decoded* f32 data each way.
fn codec_throughput() -> Vec<BenchResult> {
    const N: usize = 1 << 22;
    let vals: Vec<f32> = (0..N).map(|i| (i as f32 * 0.37).sin()).collect();
    let upload = ClientUpload::Dense(vals);
    let gb = (N * 4) as f64 / 1e9;
    let mut results = Vec::new();
    for codec in [&F32LE as &'static dyn Codec, &F16LE as &'static dyn Codec] {
        let r = bench(&format!("wire encode 4M f32 [{}]", codec.name()), 1, 5, || {
            encode_upload(&upload, codec)
        });
        eprintln!("  encode {:>6}: {:>6.2} GB/s", codec.name(), gb / r.mean_s);
        results.push(r);
        let frame = encode_upload(&upload, codec);
        let mut sink = 0f32;
        let r = bench(&format!("wire decode 4M f32 [{}]", codec.name()), 1, 5, || {
            let parsed = fetchsgd::wire::Frame::parse(&frame).expect("parse");
            match parsed.body {
                fetchsgd::wire::Body::Dense { values, .. } => {
                    values.for_each(&mut |v| sink += v);
                }
                _ => unreachable!(),
            }
            sink
        });
        eprintln!("  decode {:>6}: {:>6.2} GB/s", codec.name(), gb / r.mean_s);
        results.push(r);
    }
    results
}

/// Participation sweep: the same 100-client round with a fraction of
/// clients deterministically failing, closed at a 50% quorum — what a
/// dropped-slot round costs on top of a full one (extra membership
/// bookkeeping plus the finalize-at-quorum renormalization scale over
/// the merged table).
fn participation_round_bench(fail_mod: usize, label: &str) -> anyhow::Result<BenchResult> {
    const DIM: usize = 200_000;
    const ROWS: usize = 5;
    const COLS: usize = 4096;
    const SEED: u64 = 7;
    const COHORT: usize = 100;

    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED)?;
    let dataset = SimDataset { num_clients: 10_000 };
    let client = SimFlakyClient {
        inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 8 },
        fail: (0..COHORT).filter(|c| fail_mod > 0 && c % fail_mod == 0).collect(),
    };
    let expect_drop = client.fail.len();
    let mut server = FetchSgdServer::new(
        ROWS, COLS, SEED, DIM, 1000, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
    )?;
    let participants: Vec<usize> = (0..COHORT).collect();
    let mut w = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut round = 0u64;
    let policy = QuorumPolicy::new(0.5, 0, 0)?;
    Ok(bench(&format!("engine round W=100 d=200k quorum=0.5 {label}"), 1, 5, || {
        round += 1;
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: round,
            threads: 0,
            wire: None,
            policy: &policy,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .expect("sim round");
        assert_eq!(out.membership.summary().dropped_slots, expect_drop);
        let update = server.finish(&out.merged, 0.1).expect("server finish");
        pipeline.recycle(out.merged);
        update.apply(&mut w);
        update
    }))
}

fn participation_sweep() -> anyhow::Result<Vec<BenchResult>> {
    let mut results = Vec::new();
    // fail_mod 0 = full cohort; 5 = 20% dropped; 2 = 50% dropped (the
    // quorum floor).
    for (fail_mod, label) in [(0usize, "arrive=100%"), (5, "arrive=80%"), (2, "arrive=50%")] {
        let r = participation_round_bench(fail_mod, label)?;
        eprintln!("  {label:<12} {:>8.1} ms/round", r.mean_s * 1e3);
        results.push(r);
    }
    Ok(results)
}

fn engine_scaling() -> anyhow::Result<Vec<BenchResult>> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4];
    // Workers pull individual slots off the round pipeline, so thread
    // counts keep paying off up to the cohort size (the old whole-shard
    // scheduler capped useful parallelism at MAX_SHARDS = 16).
    if cores > 4 {
        counts.push(cores);
    }
    counts.dedup();
    let mut results = Vec::new();
    let mut base = None;
    for &t in &counts {
        let r = engine_round_bench(t, None)?;
        if t == 1 {
            base = Some(r.mean_s);
        }
        if let Some(b) = base {
            eprintln!(
                "  threads={t:<3} {:>8.1} ms/round  speedup {:.2}x",
                r.mean_s * 1e3,
                b / r.mean_s
            );
        }
        results.push(r);
    }
    // Wire-mode overhead at the widest sweep point.
    let wide = *counts.last().unwrap();
    for codec in [&F32LE as &'static dyn Codec, &F16LE as &'static dyn Codec] {
        let r = engine_round_bench(wide, Some(codec))?;
        eprintln!(
            "  threads={wide:<3} {:>8.1} ms/round  (wire={})",
            r.mean_s * 1e3,
            codec.name()
        );
        results.push(r);
    }
    Ok(results)
}

fn main() -> anyhow::Result<()> {
    eprintln!("== round engine scaling (simulated 100-client fetchsgd cohort) ==");
    let mut results = engine_scaling()?;

    eprintln!("== participation sweep (full vs 80% vs 50% arrival at a 0.5 quorum) ==");
    results.extend(participation_sweep()?);

    eprintln!("== wire codec throughput (encode/decode, dense 4M-value payload) ==");
    results.extend(codec_throughput());

    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_round: artifacts/ missing — skipping PJRT round decomposition");
        print_table("round latency", &results);
        return Ok(());
    }
    let runtime = Arc::new(Runtime::cpu()?);
    let manifest = Manifest::load(&dir)?;

    for task in ["smoke", "cifar10", "persona"] {
        if manifest.task(task).is_err() {
            continue;
        }
        let arts = TaskArtifacts::new(runtime.clone(), &manifest, task)?;
        let tm = arts.manifest.clone();
        let cols = *tm.sketch.cols_options.iter().max().unwrap();
        let w = arts.init_weights()?;
        let ds = build_dataset(&tm, &DataScale::smoke())?;
        let batch = ds.client_batch(0, 1);
        let exe = arts.executable(&TaskArtifacts::client_step_kind(cols))?;

        results.push(bench(&format!("{task}: data gen (1 batch)"), 2, 10, || {
            ds.client_batch(0, 2)
        }));
        results.push(bench(&format!("{task}: client_step d={} c={cols}", tm.dim), 2, 6, || {
            run_client_step(&exe, &w, &batch, tm.sketch.rows, cols, tm.sketch.seed).unwrap()
        }));

        // Server-side cost at this task's geometry.
        let uploads: Vec<CountSketch> = (0..8)
            .map(|i| {
                let mut g = vec![0f32; tm.dim];
                let mut rng = fetchsgd::util::Rng::new(i);
                for x in g.iter_mut() {
                    *x = rng.next_gaussian() as f32;
                }
                CountSketch::encode(tm.sketch.rows, cols, tm.sketch.seed, &g).unwrap()
            })
            .collect();
        let mut momentum =
            CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
        let mut error = CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
        results.push(bench(&format!("{task}: server round W=8 k=1000"), 1, 6, || {
            let mut round =
                CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
            for s in &uploads {
                round.add_scaled(s, 0.125);
            }
            momentum.scale(0.9);
            momentum.add_scaled(&round, 1.0);
            error.add_scaled(&momentum, 0.1);
            let delta = error.top_k(1000.min(tm.dim));
            error.zero_out_sparse(&delta);
            delta
        }));
    }

    print_table("round latency decomposition", &results);
    Ok(())
}
