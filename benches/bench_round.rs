//! End-to-end round latency and round-engine scaling.
//!
//! Set `BENCH_JSON=<path>` to also emit machine-readable results (the
//! committed `BENCH_*.json` baselines); `BENCH_SMOKE=1` runs only
//! short-iteration absorb-scaling and relay fan-out passes (the CI
//! smoke step).
//!
//! Six sections:
//!
//! 0. **Absorb scaling (no artifacts needed)** — N workers racing
//!    pre-encoded sketch frames into one in-flight round: the PR-6
//!    per-shard-lock absorber vs the pre-PR-6 single-outer-lock design
//!    (reconstructed as a `Mutex` around the whole round), both
//!    measured in the same run at parallelism 1/4/8. The merged bits
//!    are identical; only the wall clock moves.
//! 1. **Engine throughput (no artifacts needed)** — a 100-client
//!    FetchSGD cohort of simulated clients (synthetic gradient +
//!    client-side sketch encode, the same CPU shape as the real client
//!    step) driven through the parallel round engine at 1/2/4/N
//!    threads. Reports rounds/s and speedup vs single-thread; the
//!    shard-merge design keeps all of these bitwise identical.
//! 2. **Participation sweep (no artifacts needed)** — the same cohort
//!    with 0% / 20% / 50% of clients dropped at a 0.5 quorum, so the
//!    cost of membership bookkeeping and dropped-slot renormalization
//!    shows up in the perf trajectory.
//! 3. **Relay fan-out (no artifacts needed)** — the same served round
//!    flat (4 direct socket workers) vs through a 2-level tree (2
//!    relays) at downstream fan-out 4 and 16, over loopback TCP. Each
//!    result's `elements` field records the measured root-link bytes
//!    per round, which must not move with fan-out: the root sees one
//!    merged frame per relay no matter how many workers sit below.
//! 4. **Codec throughput (no artifacts needed)** — encode/decode GB/s
//!    per wire codec over a dense-payload-sized value buffer, bounding
//!    what wire mode costs on top of client compute.
//! 5. **Artifact round decomposition (requires `make artifacts`)** —
//!    client compute (PJRT execution of the fused grad+sketch HLO),
//!    server sketch update, and data generation, establishing where the
//!    bottleneck sits (the paper's contribution is the coordinator; it
//!    must not dominate).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use fetchsgd::bench_util::{bench, bench_throughput, print_table, write_json_suite, BenchResult};
use fetchsgd::cohort::QuorumPolicy;
use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{sim_artifacts, SimDataset, SimFlakyClient, SimSketchClient};
use fetchsgd::compression::{ClientUpload, ServerAggregator};
use fetchsgd::coordinator::engine;
use fetchsgd::model::{build_dataset, DataScale};
use fetchsgd::runtime::artifact::{Manifest, TaskArtifacts};
use fetchsgd::runtime::exec::run_client_step;
use fetchsgd::runtime::Runtime;
use fetchsgd::sketch::CountSketch;
use fetchsgd::trace::TraceSink;
use fetchsgd::wire::{encode_upload, Codec, F16LE, F32LE};

/// One simulated FetchSGD round (client compute + sharded aggregation +
/// server finish) at a given worker count, optionally through the wire
/// encoding. Scratch accumulators are reused across iterations exactly
/// as the Trainer reuses them across rounds.
fn engine_round_bench(
    threads: usize,
    wire: Option<&'static dyn Codec>,
) -> anyhow::Result<BenchResult> {
    const DIM: usize = 200_000;
    const ROWS: usize = 5;
    const COLS: usize = 4096;
    const SEED: u64 = 7;
    const COHORT: usize = 100;

    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED)?;
    let dataset = SimDataset { num_clients: 10_000 };
    let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 8 };
    let mut server = FetchSgdServer::new(
        ROWS, COLS, SEED, DIM, 1000, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
    )?;
    let participants: Vec<usize> = (0..COHORT).collect();
    let mut w = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut round = 0u64;
    let tag = wire.map(|c| c.name()).unwrap_or("off");
    let policy = QuorumPolicy::strict();
    Ok(bench(&format!("engine round W=100 d=200k threads={threads} wire={tag}"), 1, 5, || {
        round += 1;
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: round,
            threads,
            wire,
            policy: &policy,
            round,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .expect("sim round");
        let update = server.finish(&out.merged, 0.1).expect("server finish");
        pipeline.recycle(out.merged);
        update.apply(&mut w);
        update
    }))
}

/// Encode/decode throughput per codec over a dense 4M-value payload
/// (16 MB of f32): GB/s of *decoded* f32 data each way.
fn codec_throughput() -> Vec<BenchResult> {
    const N: usize = 1 << 22;
    let vals: Vec<f32> = (0..N).map(|i| (i as f32 * 0.37).sin()).collect();
    let upload = ClientUpload::Dense(vals);
    let gb = (N * 4) as f64 / 1e9;
    let mut results = Vec::new();
    for codec in [&F32LE as &'static dyn Codec, &F16LE as &'static dyn Codec] {
        let r = bench(&format!("wire encode 4M f32 [{}]", codec.name()), 1, 5, || {
            encode_upload(&upload, codec)
        });
        eprintln!("  encode {:>6}: {:>6.2} GB/s", codec.name(), gb / r.mean_s);
        results.push(r);
        let frame = encode_upload(&upload, codec);
        let mut sink = 0f32;
        let r = bench(&format!("wire decode 4M f32 [{}]", codec.name()), 1, 5, || {
            let parsed = fetchsgd::wire::Frame::parse(&frame).expect("parse");
            match parsed.body {
                fetchsgd::wire::Body::Dense { values, .. } => {
                    values.for_each(&mut |v| sink += v);
                }
                _ => unreachable!(),
            }
            sink
        });
        eprintln!("  decode {:>6}: {:>6.2} GB/s", codec.name(), gb / r.mean_s);
        results.push(r);
    }
    results
}

/// Participation sweep: the same 100-client round with a fraction of
/// clients deterministically failing, closed at a 50% quorum — what a
/// dropped-slot round costs on top of a full one (extra membership
/// bookkeeping plus the finalize-at-quorum renormalization scale over
/// the merged table).
fn participation_round_bench(fail_mod: usize, label: &str) -> anyhow::Result<BenchResult> {
    const DIM: usize = 200_000;
    const ROWS: usize = 5;
    const COLS: usize = 4096;
    const SEED: u64 = 7;
    const COHORT: usize = 100;

    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED)?;
    let dataset = SimDataset { num_clients: 10_000 };
    let client = SimFlakyClient {
        inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 8 },
        fail: (0..COHORT).filter(|c| fail_mod > 0 && c % fail_mod == 0).collect(),
    };
    let expect_drop = client.fail.len();
    let mut server = FetchSgdServer::new(
        ROWS, COLS, SEED, DIM, 1000, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
    )?;
    let participants: Vec<usize> = (0..COHORT).collect();
    let mut w = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut round = 0u64;
    let policy = QuorumPolicy::new(0.5, 0, 0)?;
    Ok(bench(&format!("engine round W=100 d=200k quorum=0.5 {label}"), 1, 5, || {
        round += 1;
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: round,
            threads: 0,
            wire: None,
            policy: &policy,
            round,
            trace: None,
        };
        let out =
            engine::run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
                .expect("sim round");
        assert_eq!(out.membership.summary().dropped_slots, expect_drop);
        let update = server.finish(&out.merged, 0.1).expect("server finish");
        pipeline.recycle(out.merged);
        update.apply(&mut w);
        update
    }))
}

fn participation_sweep() -> anyhow::Result<Vec<BenchResult>> {
    let mut results = Vec::new();
    // fail_mod 0 = full cohort; 5 = 20% dropped; 2 = 50% dropped (the
    // quorum floor).
    for (fail_mod, label) in [(0usize, "arrive=100%"), (5, "arrive=80%"), (2, "arrive=50%")] {
        let r = participation_round_bench(fail_mod, label)?;
        eprintln!("  {label:<12} {:>8.1} ms/round", r.mean_s * 1e3);
        results.push(r);
    }
    Ok(results)
}

/// Absorb scaling: the server-side fan-in alone (no client compute, no
/// reduce), workers pulling pre-encoded sketch frames off a shared
/// cursor and offering them to the in-flight round. The sharded-lock
/// rows use the production `&self` offer path; the single-lock rows
/// serialize every offer through one outer `Mutex` — the pre-PR-6
/// design, measured in the same run as the baseline the new absorber
/// is judged against.
fn absorb_scaling(smoke: bool) -> anyhow::Result<Vec<BenchResult>> {
    use fetchsgd::compression::UploadSpec;
    use fetchsgd::sketch::CountSketch;

    const ROWS: usize = 5;
    const COLS: usize = 16384;
    const DIM: usize = 200_000;
    const SEED: u64 = 7;
    let slots: usize = if smoke { 16 } else { 64 };
    let (warmup, iters) = if smoke { (1, 2) } else { (2, 8) };

    let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
    let frames: Vec<Vec<u8>> = (0..slots)
        .map(|i| {
            let mut rng = fetchsgd::util::Rng::new(0xAB50 + i as u64);
            let g: Vec<f32> = (0..DIM).map(|_| rng.next_gaussian() as f32).collect();
            let sk = CountSketch::encode(ROWS, COLS, SEED, &g).unwrap();
            encode_upload(&ClientUpload::Sketch(sk), &F32LE)
        })
        .collect();
    let weights = vec![1.0 / slots as f32; slots];
    let cells = (slots * ROWS * COLS) as u64;
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    let mut results = Vec::new();
    let mut speeds: Vec<(usize, f64, f64, f64)> = Vec::new();

    for &threads in &[1usize, 4, 8] {
        let r = bench_throughput(
            &format!("absorb {slots} sketch frames (5x16384) sharded-lock T={threads}"),
            warmup,
            iters,
            cells,
            || {
                let round = pipeline.begin(&spec, weights.clone()).expect("begin");
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| loop {
                            let i = cursor.fetch_add(1, Ordering::SeqCst);
                            if i >= slots {
                                break;
                            }
                            round.offer_frame_bytes(i, &frames[i]).expect("offer");
                        });
                    }
                });
                assert!(round.is_complete());
                let stats = round.absorb_stats();
                // Skip the reduce: this section isolates the absorb
                // path. Shards go back to the pool for the next iter.
                pipeline.abort(round);
                stats
            },
        );
        let sharded = cells as f64 / r.mean_s;
        results.push(r);

        let r = bench_throughput(
            &format!("absorb {slots} sketch frames (5x16384) single-lock T={threads}"),
            warmup,
            iters,
            cells,
            || {
                let round = Mutex::new(pipeline.begin(&spec, weights.clone()).expect("begin"));
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| loop {
                            let i = cursor.fetch_add(1, Ordering::SeqCst);
                            if i >= slots {
                                break;
                            }
                            let guard = round.lock().expect("round lock");
                            guard.offer_frame_bytes(i, &frames[i]).expect("offer");
                            drop(guard);
                        });
                    }
                });
                pipeline.abort(round.into_inner().expect("round lock"));
            },
        );
        let single = cells as f64 / r.mean_s;
        results.push(r);

        // The sharded path again with a TraceSink attached: the cost
        // of per-slot timeline events on the hot absorb path. The
        // trace-off row above is the one compared against prior
        // baselines; this row bounds the observability overhead.
        let trace_path = std::env::temp_dir()
            .join(format!("fsgd_bench_absorb_trace_{}.jsonl", std::process::id()));
        let sink = Arc::new(TraceSink::create(&trace_path, "engine", "bench").expect("sink"));
        let r = bench_throughput(
            &format!("absorb {slots} sketch frames (5x16384) sharded-lock T={threads} trace=on"),
            warmup,
            iters,
            cells,
            || {
                let mut round = pipeline.begin(&spec, weights.clone()).expect("begin");
                round.attach_trace(sink.clone(), 0);
                let round = round;
                let cursor = AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..threads {
                        s.spawn(|| loop {
                            let i = cursor.fetch_add(1, Ordering::SeqCst);
                            if i >= slots {
                                break;
                            }
                            round.offer_frame_bytes(i, &frames[i]).expect("offer");
                        });
                    }
                });
                assert!(round.is_complete());
                pipeline.abort(round);
            },
        );
        let traced = cells as f64 / r.mean_s;
        results.push(r);
        drop(sink);
        std::fs::remove_file(&trace_path).ok();
        speeds.push((threads, sharded, single, traced));
    }
    for (threads, sharded, single, traced) in speeds {
        eprintln!(
            "  T={threads:<2} sharded {:>7.2} Mcells/s  single-lock {:>7.2} Mcells/s  \
             ratio {:.2}x  traced {:>7.2} Mcells/s ({:.1}% overhead)",
            sharded / 1e6,
            single / 1e6,
            sharded / single,
            traced / 1e6,
            (sharded / traced - 1.0) * 100.0
        );
    }
    Ok(results)
}

/// Frame-absorb kernels: the simd dispatch vs its always-compiled
/// scalar twin over a dense 4M-value payload, for both wire codecs —
/// the `dst += w * decode(bytes)` walk that every zero-copy absorb
/// rides. With the `simd` feature off both rows run the scalar code;
/// with it on the spread is the SSE2 win (f16le also folds the
/// lane-wise f16→f32 widening in). Bits are identical either way.
fn absorb_kernels() -> Vec<BenchResult> {
    use fetchsgd::serialize::le::extend_f32_le;
    use fetchsgd::util::simd::{self, scalar};
    use fetchsgd::wire::codec::f32_to_f16_bits;

    const N: usize = 1 << 22;
    let vals: Vec<f32> = (0..N).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut f32bytes = Vec::with_capacity(N * 4);
    extend_f32_le(&mut f32bytes, &vals);
    let mut f16bytes = Vec::with_capacity(N * 2);
    for &v in &vals {
        f16bytes.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
    let mut dst = vec![0f32; N];
    let mut results = Vec::new();
    let mut rates: Vec<(&str, f64, f64)> = Vec::new();
    {
        let r = bench_throughput("absorb 4M f32le DISPATCH", 1, 6, N as u64, || {
            simd::axpy_f32_le(&f32bytes, 0.01, &mut dst)
        });
        let disp = N as f64 / r.mean_s;
        results.push(r);
        let r = bench_throughput("absorb 4M f32le SCALAR", 1, 6, N as u64, || {
            scalar::axpy_f32_le(&f32bytes, 0.01, &mut dst)
        });
        rates.push(("f32le", disp, N as f64 / r.mean_s));
        results.push(r);
    }
    {
        let r = bench_throughput("absorb 4M f16le DISPATCH", 1, 6, N as u64, || {
            simd::axpy_f16_le(&f16bytes, 0.01, &mut dst)
        });
        let disp = N as f64 / r.mean_s;
        results.push(r);
        let r = bench_throughput("absorb 4M f16le SCALAR", 1, 6, N as u64, || {
            scalar::axpy_f16_le(&f16bytes, 0.01, &mut dst)
        });
        rates.push(("f16le", disp, N as f64 / r.mean_s));
        results.push(r);
    }
    for (codec, disp, scal) in rates {
        eprintln!(
            "  {codec:<6} dispatch {:>7.1} Mval/s  scalar {:>7.1} Mval/s  ratio {:.2}x",
            disp / 1e6,
            scal / 1e6,
            disp / scal
        );
    }
    results
}

/// Relay fan-out: a flat served round vs a 2-level tree (2 relays) at
/// downstream fan-out 4 and 16, over loopback TCP. The wall clock
/// tracks what the extra hop costs; the `elements` field rides along
/// with the measured root-link bytes per round, which must be
/// independent of fan-out — the root receives one merged frame per
/// relay regardless of how many workers sit below it. `smoke` shrinks
/// the geometry and drops the wide fan-out point so CI can drive the
/// full relay socket path (bind, nested handshake, merged upload,
/// shutdown) in seconds.
fn relay_fanout(smoke: bool) -> anyhow::Result<Vec<BenchResult>> {
    use fetchsgd::relay::{Relay, RelayOptions};
    use fetchsgd::transport::{join, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions};

    let dim: usize = if smoke { 20_000 } else { 200_000 };
    const ROWS: usize = 5;
    let cols: usize = if smoke { 1024 } else { 4096 };
    const SEED: u64 = 7;
    let cohort: usize = if smoke { 8 } else { 64 };
    const RELAYS: usize = 2;
    let (warmup, iters) = if smoke { (1, 2) } else { (1, 4) };
    let timeout = std::time::Duration::from_secs(60);

    let dataset = SimDataset { num_clients: 10_000 };
    let client = SimSketchClient { rows: ROWS, cols, seed: SEED, dim, heavy: 8 };
    let participants: Vec<usize> = (0..cohort).collect();
    let mut results = Vec::new();

    // fanout 0 = the flat baseline: 4 direct workers with the shard
    // layout pinned to the relay count, so the fold matches the trees
    // bit for bit and only topology moves the clock. Smoke keeps one
    // tree point — the socket path is the same at any fan-out.
    let mut configs = vec![("flat workers=4", 0usize), ("tree fanout=4", 4)];
    if !smoke {
        configs.push(("tree fanout=16", 16));
    }
    for (label, fanout) in configs {
        let mut server = FetchSgdServer::new(
            ROWS, cols, SEED, dim, 1000, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )?;
        // Smoke mode doubles as the CI trace fixture: every tier of
        // the tree writes a trace file under target/, and a later CI
        // step pipes them through `fetchsgd trace-summary` to pin the
        // CLI end to end. Full runs keep tracing off so the committed
        // rows stay comparable across baselines.
        let trace_root = if smoke && fanout > 0 {
            Some(Arc::new(TraceSink::create(
                std::path::Path::new("target/bench_trace_root.jsonl"),
                "root",
                "tcp:loopback",
            )?))
        } else {
            None
        };
        let opts = if fanout == 0 {
            ServeOptions {
                workers: 4,
                shards: RELAYS,
                read_timeout: timeout,
                accept_timeout: timeout,
                ..Default::default()
            }
        } else {
            ServeOptions {
                workers: 0,
                relay_children: RELAYS,
                read_timeout: timeout,
                accept_timeout: timeout,
                trace: trace_root.clone(),
                ..Default::default()
            }
        };
        let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts)?;
        let root = srv.local_endpoint()?;
        let mut w = vec![0f32; dim];
        let cref = &client;
        let dref = &dataset;
        let (mut r, root_bytes) = std::thread::scope(|s| {
            let mut spawn_worker = |ep: Endpoint| {
                s.spawn(move || {
                    let artifacts = sim_artifacts(dim, ROWS, cols, SEED).unwrap();
                    let opts = JoinOptions { read_timeout: Some(timeout), ..Default::default() };
                    let _ = join(&ep, cref, dref, &artifacts, &opts);
                });
            };
            if fanout == 0 {
                for _ in 0..4 {
                    spawn_worker(root.clone());
                }
            } else {
                for ri in 0..RELAYS {
                    let mut node = Relay::bind(
                        &Endpoint::Tcp("127.0.0.1:0".into()),
                        RelayOptions {
                            workers: fanout,
                            read_timeout: timeout,
                            accept_timeout: timeout,
                            trace_path: smoke.then(|| {
                                format!("target/bench_trace_relay{ri}.jsonl").into()
                            }),
                            ..Default::default()
                        },
                    )
                    .expect("relay bind");
                    let down = node.local_endpoint().expect("relay endpoint");
                    let up = root.clone();
                    s.spawn(move || {
                        let _ = node.run(&up);
                    });
                    for _ in 0..fanout {
                        spawn_worker(down.clone());
                    }
                }
            }
            let mut round = 0u64;
            let mut bytes = 0u64;
            let mut rounds = 0u64;
            let name = format!("served round W={cohort} d={}k {label}", dim / 1000);
            let r = bench(&name, warmup, iters, || {
                round += 1;
                let sizes: Vec<f32> =
                    participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
                let params = RoundParams {
                    round,
                    round_seed: round,
                    lr: 0.1,
                    participants: &participants,
                    client_sizes: &sizes,
                };
                let stats = srv.run_round(&mut server, &params, &mut w).expect("served round");
                bytes += stats.transport_bytes;
                rounds += 1;
                stats.participants
            });
            srv.shutdown();
            (r, bytes / rounds)
        });
        if let Some(t) = &trace_root {
            t.flush().expect("flushing root trace");
        }
        r.elements = Some(root_bytes);
        eprintln!(
            "  {label:<16} {:>8.1} ms/round  root link {:>9} B/round",
            r.mean_s * 1e3,
            root_bytes
        );
        results.push(r);
    }
    Ok(results)
}

fn engine_scaling() -> anyhow::Result<Vec<BenchResult>> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut counts = vec![1usize, 2, 4];
    // Workers pull individual slots off the round pipeline, so thread
    // counts keep paying off up to the cohort size (the old whole-shard
    // scheduler capped useful parallelism at MAX_SHARDS = 16).
    if cores > 4 {
        counts.push(cores);
    }
    counts.dedup();
    let mut results = Vec::new();
    let mut base = None;
    for &t in &counts {
        let r = engine_round_bench(t, None)?;
        if t == 1 {
            base = Some(r.mean_s);
        }
        if let Some(b) = base {
            eprintln!(
                "  threads={t:<3} {:>8.1} ms/round  speedup {:.2}x",
                r.mean_s * 1e3,
                b / r.mean_s
            );
        }
        results.push(r);
    }
    // Wire-mode overhead at the widest sweep point.
    let wide = *counts.last().unwrap();
    for codec in [&F32LE as &'static dyn Codec, &F16LE as &'static dyn Codec] {
        let r = engine_round_bench(wide, Some(codec))?;
        eprintln!(
            "  threads={wide:<3} {:>8.1} ms/round  (wire={})",
            r.mean_s * 1e3,
            codec.name()
        );
        results.push(r);
    }
    Ok(results)
}

fn main() -> anyhow::Result<()> {
    // CI smoke mode: the absorb-scaling section at short iteration
    // counts, plus a shrunk relay fan-out pass so the relay socket
    // path (bind, nested handshake, merged upload, shutdown) is
    // exercised too — enough to catch a crash, a deadlock, or an
    // incomplete round without paying the full sweep.
    if std::env::var("BENCH_SMOKE").is_ok() {
        eprintln!("== absorb scaling (BENCH_SMOKE: short iterations) ==");
        let mut results = absorb_scaling(true)?;
        eprintln!("== relay fan-out (BENCH_SMOKE: flat vs one small tree) ==");
        results.extend(relay_fanout(true)?);
        print_table("round smoke", &results);
        write_json_suite("round_smoke", &results);
        return Ok(());
    }

    eprintln!("== absorb scaling (sharded-lock vs single-lock, same run) ==");
    let mut results = absorb_scaling(false)?;

    eprintln!("== round engine scaling (simulated 100-client fetchsgd cohort) ==");
    results.extend(engine_scaling()?);

    eprintln!("== participation sweep (full vs 80% vs 50% arrival at a 0.5 quorum) ==");
    results.extend(participation_sweep()?);

    eprintln!("== relay fan-out (flat vs 2-level tree over loopback TCP) ==");
    results.extend(relay_fanout(false)?);

    eprintln!("== wire codec throughput (encode/decode, dense 4M-value payload) ==");
    results.extend(codec_throughput());

    eprintln!("== absorb kernels (simd dispatch vs scalar twin, both codecs) ==");
    results.extend(absorb_kernels());

    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_round: artifacts/ missing — skipping PJRT round decomposition");
        print_table("round latency", &results);
        write_json_suite("round", &results);
        return Ok(());
    }
    let runtime = Arc::new(Runtime::cpu()?);
    let manifest = Manifest::load(&dir)?;

    for task in ["smoke", "cifar10", "persona"] {
        if manifest.task(task).is_err() {
            continue;
        }
        let arts = TaskArtifacts::new(runtime.clone(), &manifest, task)?;
        let tm = arts.manifest.clone();
        let cols = *tm.sketch.cols_options.iter().max().unwrap();
        let w = arts.init_weights()?;
        let ds = build_dataset(&tm, &DataScale::smoke())?;
        let batch = ds.client_batch(0, 1);
        let exe = arts.executable(&TaskArtifacts::client_step_kind(cols))?;

        results.push(bench(&format!("{task}: data gen (1 batch)"), 2, 10, || {
            ds.client_batch(0, 2)
        }));
        results.push(bench(&format!("{task}: client_step d={} c={cols}", tm.dim), 2, 6, || {
            run_client_step(&exe, &w, &batch, tm.sketch.rows, cols, tm.sketch.seed).unwrap()
        }));

        // Server-side cost at this task's geometry.
        let uploads: Vec<CountSketch> = (0..8)
            .map(|i| {
                let mut g = vec![0f32; tm.dim];
                let mut rng = fetchsgd::util::Rng::new(i);
                for x in g.iter_mut() {
                    *x = rng.next_gaussian() as f32;
                }
                CountSketch::encode(tm.sketch.rows, cols, tm.sketch.seed, &g).unwrap()
            })
            .collect();
        let mut momentum =
            CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
        let mut error = CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
        results.push(bench(&format!("{task}: server round W=8 k=1000"), 1, 6, || {
            let mut round =
                CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed).unwrap();
            for s in &uploads {
                round.add_scaled(s, 0.125);
            }
            momentum.scale(0.9);
            momentum.add_scaled(&round, 1.0);
            error.add_scaled(&momentum, 0.1);
            let delta = error.top_k(1000.min(tm.dim));
            error.zero_out_sparse(&delta);
            delta
        }));
    }

    print_table("round latency decomposition", &results);
    write_json_suite("round", &results);
    Ok(())
}
