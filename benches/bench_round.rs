//! End-to-end round latency (requires `make artifacts`).
//!
//! Splits one federated round into its cost components: client compute
//! (PJRT execution of the fused grad+sketch HLO), server sketch update,
//! and data generation — establishing where the bottleneck sits (the
//! paper's contribution is the coordinator; it must not dominate).

use std::rc::Rc;

use fetchsgd::bench_util::{bench, print_table};
use fetchsgd::model::{build_dataset, DataScale};
use fetchsgd::runtime::artifact::{Manifest, TaskArtifacts};
use fetchsgd::runtime::exec::run_client_step;
use fetchsgd::runtime::Runtime;
use fetchsgd::sketch::CountSketch;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_round: artifacts/ missing — run `make artifacts` first (skipping)");
        return Ok(());
    }
    let runtime = Rc::new(Runtime::cpu()?);
    let manifest = Manifest::load(&dir)?;
    let mut results = Vec::new();

    for task in ["smoke", "cifar10", "persona"] {
        if manifest.task(task).is_err() {
            continue;
        }
        let arts = TaskArtifacts::new(runtime.clone(), &manifest, task)?;
        let tm = arts.manifest.clone();
        let cols = *tm.sketch.cols_options.iter().max().unwrap();
        let w = arts.init_weights()?;
        let ds = build_dataset(&tm, &DataScale::smoke())?;
        let batch = ds.client_batch(0, 1);
        let exe = arts.executable(&TaskArtifacts::client_step_kind(cols))?;

        results.push(bench(&format!("{task}: data gen (1 batch)"), 2, 10, || {
            ds.client_batch(0, 2)
        }));
        results.push(bench(&format!("{task}: client_step d={} c={cols}", tm.dim), 2, 6, || {
            run_client_step(&exe, &w, &batch, tm.sketch.rows, cols, tm.sketch.seed).unwrap()
        }));

        // Server-side cost at this task's geometry.
        let uploads: Vec<CountSketch> = (0..8)
            .map(|i| {
                let mut g = vec![0f32; tm.dim];
                let mut rng = fetchsgd::util::Rng::new(i);
                for x in g.iter_mut() {
                    *x = rng.next_gaussian() as f32;
                }
                CountSketch::encode(tm.sketch.rows, cols, tm.sketch.seed, &g)
            })
            .collect();
        let mut momentum = CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed);
        let mut error = CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed);
        results.push(bench(&format!("{task}: server round W=8 k=1000"), 1, 6, || {
            let mut round = CountSketch::zeros(tm.sketch.rows, cols, tm.dim, tm.sketch.seed);
            for s in &uploads {
                round.add_scaled(s, 0.125);
            }
            momentum.scale(0.9);
            momentum.add_scaled(&round, 1.0);
            error.add_scaled(&momentum, 0.1);
            let delta = error.top_k(1000.min(tm.dim));
            error.zero_out_sparse(&delta);
            delta
        }));
    }

    print_table("round latency decomposition", &results);
    Ok(())
}
