//! Loopback serve/join: a FetchSGD round server on a real TCP socket
//! with two in-process workers driving the client compute over it —
//! the deployment topology of the paper's Figure 1, on one machine.
//!
//! ```bash
//! cargo run --release --example serve_loopback
//! ```
//!
//! Uses the PJRT-free sim stack, so no `make artifacts` is needed. The
//! example cross-checks the served run against the in-process engine:
//! final weights must be bitwise identical — the transport is a
//! deployment knob, not a numerics knob. For a real two-process run
//! over the AOT artifacts, see `fetchsgd serve` / `fetchsgd join`.

use std::time::Duration;

use fetchsgd::compression::aggregate::{PipelineOptions, RoundPipeline};
use fetchsgd::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
use fetchsgd::compression::sim::{sim_artifacts, SimDataset, SimSketchClient};
use fetchsgd::compression::ServerAggregator;
use fetchsgd::coordinator::{engine, ClientSelector};
use fetchsgd::transport::{join, Endpoint, JoinOptions, RoundParams, RoundServer, ServeOptions};
use fetchsgd::util::rng::derive_seed;

const DIM: usize = 20_000;
const ROWS: usize = 5;
const COLS: usize = 1024;
const SEED: u64 = 42;
const ROUNDS: usize = 5;
const COHORT: usize = 10;
const WORKERS: usize = 2;
const NUM_CLIENTS: usize = 100;

fn make_server() -> FetchSgdServer {
    FetchSgdServer::new(ROWS, COLS, SEED, DIM, 32, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
        .unwrap()
}

fn main() -> anyhow::Result<()> {
    let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
    let selector = ClientSelector::new(NUM_CLIENTS, COHORT, SEED);

    // -- served run: server on a TCP socket, workers join over it --
    let opts = ServeOptions { workers: WORKERS, ..Default::default() };
    let mut srv = RoundServer::bind(&Endpoint::Tcp("127.0.0.1:0".into()), opts)?;
    let ep = srv.local_endpoint()?;
    println!("serving on {ep} for {WORKERS} workers, {ROUNDS} rounds of W={COHORT}");

    let mut agg = make_server();
    let mut w = vec![0f32; DIM];
    let mut total_wire = 0u64;
    std::thread::scope(|s| -> anyhow::Result<()> {
        for id in 0..WORKERS {
            let ep = ep.clone();
            let client = &client;
            s.spawn(move || {
                let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
                let dataset = SimDataset { num_clients: NUM_CLIENTS };
                let opts = JoinOptions {
                    read_timeout: Some(Duration::from_secs(30)),
                    ..Default::default()
                };
                let sum = join(&ep, client, &dataset, &artifacts, &opts).unwrap();
                println!(
                    "worker {id}: {} uploads over {} rounds ({} B up, {} B down)",
                    sum.uploads, sum.rounds, sum.bytes_sent, sum.bytes_received
                );
            });
        }
        for round in 0..ROUNDS {
            let participants = selector.select(round);
            let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
            let params = RoundParams {
                round: round as u64,
                round_seed: derive_seed(SEED, round as u64),
                lr: 0.1,
                participants: &participants,
                client_sizes: &sizes,
            };
            let stats = srv.run_round(&mut agg, &params, &mut w)?;
            total_wire += stats.transport_bytes;
            println!(
                "round {round}: loss {:.4} nnz {} wire {} B (frames: {} B/up, {} B/down)",
                stats.mean_loss,
                stats.update_nnz,
                stats.transport_bytes,
                stats.wire_upload_bytes_per_client,
                stats.wire_download_bytes_per_client
            );
        }
        srv.shutdown();
        Ok(())
    })?;

    // -- in-process reference: same seeds, same math, no sockets --
    let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED)?;
    let dataset = SimDataset { num_clients: NUM_CLIENTS };
    let mut agg_ref = make_server();
    let mut w_ref = vec![0f32; DIM];
    let mut pipeline = RoundPipeline::new(PipelineOptions::default());
    for round in 0..ROUNDS {
        let participants = selector.select(round);
        let sizes: Vec<f32> = participants.iter().map(|&c| 1.0 + (c % 5) as f32).collect();
        let lambdas = agg_ref.begin_round(&sizes);
        let policy = fetchsgd::cohort::QuorumPolicy::strict();
        let ctx = engine::RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w_ref,
            lr: 0.1,
            round_seed: derive_seed(SEED, round as u64),
            threads: 0,
            wire: None,
            policy: &policy,
            round: round as u64,
            trace: None,
        };
        let spec = agg_ref.upload_spec();
        let out = engine::run_round(&ctx, &participants, &lambdas, &spec, &mut pipeline)?;
        let update = agg_ref.finish(&out.merged, 0.1)?;
        pipeline.recycle(out.merged);
        update.apply(&mut w_ref);
    }

    let identical = w
        .iter()
        .zip(&w_ref)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    anyhow::ensure!(identical, "served weights diverged from the in-process engine");
    println!("\nserved == in-process, bitwise ({total_wire} B on the wire total)");
    Ok(())
}
