//! CIFAR-analog non-i.i.d. scenario: the paper's headline regime
//! (§5.1) — every client holds a handful of images of a *single* class,
//! so local gradients are wildly unrepresentative. Compares FetchSGD
//! against local top-k and FedAvg at similar communication budgets.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example cifar_noniid
//! ```

use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::Trainer;
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;
use std::sync::Arc;

fn base() -> TrainConfig {
    TrainConfig {
        task: "cifar10".into(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds: 30,
        clients_per_round: 10,
        // peak lr tuned on the uncompressed baseline (paper §5 protocol)
        lr: LrSchedule::Triangular { peak: 0.02, pivot: 0.2 },
        scale: DataScale {
            num_clients: 100,
            samples_per_client: 5, // 5 images, one class per client
            eval_batches: 6,
            partition: "label_skew".into(),
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 7,
        artifacts_dir: "artifacts".into(),
        log_path: None,
        baseline_rounds: Some(30),
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

fn main() -> anyhow::Result<()> {
    let runtime = Arc::new(Runtime::cpu()?);
    let mut results = Vec::new();

    let configs: Vec<(&str, StrategyConfig)> = vec![
        ("uncompressed", StrategyConfig::Uncompressed { rho_g: 0.9 }),
        (
            "fetchsgd",
            StrategyConfig::FetchSgd {
                k: 5000,
                cols: 8192,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
        ),
        (
            "local_topk",
            StrategyConfig::LocalTopK { k: 5000, rho_g: 0.9, masking: true, local_error: false },
        ),
        ("fedavg", StrategyConfig::FedAvg { local_steps: 2, rho_g: 0.0 }),
    ];

    for (name, strat) in configs {
        let mut cfg = base();
        cfg.strategy = strat;
        if name == "fedavg" {
            cfg.rounds = 15; // FedAvg compresses by running fewer rounds
        }
        eprintln!("== training {name} ==");
        let mut t = Trainer::with_runtime(cfg, runtime.clone())?;
        let s = t.run()?;
        results.push((name, s));
    }

    println!("\n-- cifar_noniid: 1-class-per-client, 5 images each --");
    println!(
        "{:<14} {:>10} {:>10} {:>8} {:>8} {:>9}",
        "method", "train", "accuracy", "up", "down", "overall"
    );
    for (name, s) in &results {
        println!(
            "{:<14} {:>10.4} {:>9.2}% {:>7.1}x {:>7.1}x {:>8.1}x",
            name,
            s.final_loss,
            s.accuracy * 100.0,
            s.ratios.upload,
            s.ratios.download,
            s.ratios.overall
        );
    }
    Ok(())
}
