//! Quickstart: train a small model with FetchSGD through the public API.
//!
//! ```bash
//! make artifacts                # once: AOT-lower the compute graphs
//! cargo run --release --example quickstart
//! ```
//!
//! This uses the `smoke` task (tiny MLP on label-skew synthetic images,
//! 50 clients with 5 images of a single class each) and the FetchSGD
//! strategy: clients upload 5x512 Count Sketches of their gradients; the
//! server carries momentum + error accumulation in sketch space and
//! broadcasts k-sparse updates.

use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::Trainer;

fn main() -> anyhow::Result<()> {
    let mut cfg = TrainConfig::default_smoke();
    cfg.rounds = 40;
    cfg.eval_every = 10;
    cfg.verbose = true;
    cfg.lr = LrSchedule::Triangular { peak: 0.2, pivot: 0.25 };
    cfg.strategy = StrategyConfig::FetchSgd {
        k: 50,
        cols: 512,
        rho: 0.9,
        error_update: "zero_out".into(),
        error_window: "vanilla".into(),
        masking: true,
    };

    let mut trainer = Trainer::new(cfg)?;
    let summary = trainer.run()?;

    println!("\n-- quickstart result --");
    println!("final train loss : {:.4}", summary.final_loss);
    println!("eval loss        : {:.4}", summary.eval_loss);
    println!("eval accuracy    : {:.2}%", summary.accuracy * 100.0);
    println!(
        "compression      : up {:.1}x / down {:.1}x / overall {:.1}x",
        summary.ratios.upload, summary.ratios.download, summary.ratios.overall
    );
    anyhow::ensure!(summary.accuracy > 0.5, "quickstart should learn the smoke task");
    Ok(())
}
