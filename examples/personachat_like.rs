//! End-to-end driver (EXPERIMENTS.md §E2E): federated finetuning of the
//! transformer LM on the PersonaChat-analog corpus with FetchSGD, a few
//! hundred rounds, loss curve logged to `results/e2e_loss_curve.jsonl`.
//!
//! This is the system-prompt-mandated full-stack validation: synthetic
//! persona corpus (Rust) → per-client batches → PJRT execution of the
//! AOT HLO (JAX transformer fwd/bwd + Pallas Count-Sketch kernel) →
//! sketch aggregation, sketch-space momentum + error feedback, top-k
//! extraction, sparse broadcast (Rust) → held-out perplexity.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example personachat_like            # default scale
//! cargo run --release --example personachat_like -- --rounds 300
//! ```

use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::Trainer;
use fetchsgd::model::DataScale;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mut rounds = 200usize;
    let mut task = "persona".to_string();
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--rounds" => {
                rounds = args[i + 1].parse()?;
                i += 2;
            }
            "--large" => {
                task = "persona_large".to_string();
                i += 1;
            }
            other => {
                eprintln!("ignoring arg {other}");
                i += 1;
            }
        }
    }

    let cols = if task == "persona_large" { 16384 } else { 4096 };
    let cfg = TrainConfig {
        task: task.clone(),
        strategy: StrategyConfig::FetchSgd {
            k: 1000,
            cols,
            rho: 0.9,
            error_update: "zero_out".into(),
            error_window: "vanilla".into(),
            masking: true,
        },
        rounds,
        clients_per_round: 8,
        lr: LrSchedule::LinearDecay { lr: 0.25 },
        scale: DataScale {
            num_clients: 800,
            persona_max_size: 200,
            persona_alpha: 1.1,
            eval_batches: 8,
            ..DataScale::default()
        },
        eval_every: 25,
        seed: 2020,
        artifacts_dir: "artifacts".into(),
        log_path: Some("results/e2e_loss_curve.jsonl".into()),
        baseline_rounds: Some(rounds),
        verbose: true,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    };

    eprintln!("== e2e: FetchSGD finetune of {task} over 800 persona clients, {rounds} rounds ==");
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    let dim = trainer.dim();
    let summary = trainer.run()?;
    let wall = t0.elapsed().as_secs_f64();

    // Loss-curve sanity: early vs late mean training loss.
    let losses: Vec<f64> = trainer.logger.rounds.iter().map(|r| r.loss).collect();
    let head = losses[..losses.len() / 4].iter().sum::<f64>() / (losses.len() / 4) as f64;
    let tail = losses[3 * losses.len() / 4..].iter().sum::<f64>()
        / (losses.len() - 3 * losses.len() / 4) as f64;

    println!("\n-- personachat_like (e2e driver) --");
    println!("model dim          : {dim}");
    println!("rounds             : {rounds} ({wall:.0}s wall)");
    println!("train loss         : {head:.4} (first quarter) -> {tail:.4} (last quarter)");
    println!("eval loss / ppl    : {:.4} / {:.2}", summary.eval_loss, summary.perplexity);
    println!(
        "compression        : up {:.1}x / down {:.1}x / overall {:.1}x",
        summary.ratios.upload, summary.ratios.download, summary.ratios.overall
    );
    println!("loss curve         : results/e2e_loss_curve.jsonl");
    anyhow::ensure!(tail < head, "training loss should decrease ({head:.4} -> {tail:.4})");
    Ok(())
}
