//! FEMNIST-analog scenario (§5.2): writer-partitioned clients with
//! larger, more i.i.d. local datasets and only W=3 clients per round —
//! the regime *designed to favor FedAvg*. FetchSGD should remain
//! competitive (the paper's claim), which this example demonstrates.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example femnist_like
//! ```

use fetchsgd::config::{LrSchedule, StrategyConfig, TrainConfig};
use fetchsgd::coordinator::Trainer;
use fetchsgd::model::DataScale;
use fetchsgd::runtime::Runtime;
use std::sync::Arc;

fn base() -> TrainConfig {
    TrainConfig {
        task: "femnist".into(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds: 40,
        clients_per_round: 3, // paper: three clients per round
        // peak lr tuned on the uncompressed baseline (paper §5 protocol)
        lr: LrSchedule::Triangular { peak: 0.1, pivot: 0.2 },
        scale: DataScale {
            num_clients: 120,
            writer_mean_size: 40,
            eval_batches: 6,
            partition: "writer".into(),
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 11,
        artifacts_dir: "artifacts".into(),
        log_path: None,
        baseline_rounds: Some(40),
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

fn main() -> anyhow::Result<()> {
    let runtime = Arc::new(Runtime::cpu()?);
    let mut results = Vec::new();

    let runs: Vec<(&str, usize, StrategyConfig)> = vec![
        ("uncompressed", 40, StrategyConfig::Uncompressed { rho_g: 0.9 }),
        (
            "fetchsgd",
            40,
            StrategyConfig::FetchSgd {
                k: 8000,
                cols: 8192,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
        ),
        (
            "local_topk+mom",
            40,
            StrategyConfig::LocalTopK { k: 8000, rho_g: 0.9, masking: true, local_error: false },
        ),
        // FedAvg's favored configuration: 5 local steps, half the rounds.
        ("fedavg k=5", 20, StrategyConfig::FedAvg { local_steps: 5, rho_g: 0.0 }),
    ];

    for (name, rounds, strat) in runs {
        let mut cfg = base();
        cfg.rounds = rounds;
        cfg.strategy = strat;
        eprintln!("== training {name} ==");
        let mut t = Trainer::with_runtime(cfg, runtime.clone())?;
        results.push((name, t.run()?));
    }

    println!("\n-- femnist_like: writer split, ~40 imgs/client, W=3 --");
    println!("{:<16} {:>10} {:>10} {:>9}", "method", "train", "accuracy", "overall");
    for (name, s) in &results {
        println!(
            "{:<16} {:>10.4} {:>9.2}% {:>8.1}x",
            name,
            s.final_loss,
            s.accuracy * 100.0,
            s.ratios.overall
        );
    }
    Ok(())
}
